//! The `.phnsw` index artifact — one self-contained file bundling
//! everything a server needs to answer queries: the frozen CSR graph, the
//! trained [`PcaModel`], the SQ8-quantized low-dim filter store, and the
//! f32 high-dim rerank table. A process boots by [`Bundle::open`]
//! instead of re-fitting PCA and re-projecting the corpus at startup, and
//! the reconstructed searcher is bitwise identical to the one the bundle
//! was saved from (tests pin this).
//!
//! ## Format
//!
//! ```text
//!   magic "PHNB"  u32 version (1 = single-segment, 2 = segmented)
//!   u32 n_sections
//!   per section: [4-byte tag][u64 len][len payload bytes]
//! ```
//!
//! Sections (unknown tags are skipped for forward compat):
//!
//! | tag    | payload |
//! |--------|---------|
//! | `GRPH` | graph v2 image (`graph::serialize::write_to`) |
//! | `PCAM` | [`PcaModel::to_bytes`] |
//! | `LOWQ` | low-dim [`VectorStore`] blob (`store::store_from_bytes`) |
//! | `HIGH` | high-dim f32 table: `[u32 dim][u64 n][n × dim × f32-le]` |
//! | `SEGD` | shard directory: `[u32 n_shards][u8 assignment][u64 n]` |
//!
//! A **single-segment** bundle is exactly the PR-2 layout — version 1,
//! one `GRPH`/`PCAM`/`LOWQ`/`HIGH` each, no `SEGD` — and those files
//! keep loading byte-for-byte. A **segmented** bundle
//! ([`save_segmented`]) is version 2: a `SEGD` directory and the shared
//! `PCAM`, then one `GRPH`/`LOWQ`/`HIGH` group *per shard* in shard
//! order; the reader pairs the repeated groups positionally. The
//! version bump is deliberate — a pre-segmentation reader must reject a
//! sharded file loudly ("unsupported bundle version 2"), not skip the
//! unknown `SEGD` tag and silently serve the last shard as if it were
//! the whole corpus.
//!
//! **Version 3** (`super::v3`) replaces the sequential frames with an
//! up-front section directory and page-aligned payloads, so the whole
//! file can be served straight from an `mmap` with zero deserialization
//! — see the `v3` module docs for the layout. [`Bundle::open`]
//! dispatches all three versions; requesting `mmap` (via
//! [`OpenOptions`]) on a v1/v2 file is a loud error rather than a
//! silent owned fallback.
//!
//! Every declared length is validated against the remaining file bytes
//! *before* any allocation sized from it — a corrupt artifact surfaces
//! as `Err`, never as an OOM abort (same policy as
//! `graph/serialize.rs`) — and each section is decoded as soon as it is
//! read, so open never holds more than one raw payload alongside the
//! decoded index (the streaming profile of the pre-segmentation
//! reader).

use crate::dataset::VectorSet;
use crate::graph::{serialize, HnswGraph, Permutation};
use crate::pca::PcaModel;
use crate::search::{AnnEngine, PhnswParams, PhnswSearcher};
use crate::segment::{Segment, SegmentedIndex, ShardAssignment, ShardMap};
use crate::store::{store_from_bytes, VectorStore};
use anyhow::{bail, ensure, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;
use std::sync::Arc;

pub(crate) const MAGIC: &[u8; 4] = b"PHNB";
/// Classic single-segment layout (PR-2 compatible).
pub(crate) const VERSION_SINGLE: u32 = 1;
/// Segmented layout (`SEGD` + per-shard section groups).
pub(crate) const VERSION_SEGMENTED: u32 = 2;
/// Page-aligned zero-copy layout (`super::v3`), servable via mmap.
pub(crate) const VERSION_V3: u32 = 3;

pub(crate) const TAG_GRAPH: &[u8; 4] = b"GRPH";
pub(crate) const TAG_PCA: &[u8; 4] = b"PCAM";
pub(crate) const TAG_LOW: &[u8; 4] = b"LOWQ";
/// Mid-stage cascade table (v3 only): SQ8 codes of the *high*-dim rows.
pub(crate) const TAG_MID: &[u8; 4] = b"MIDQ";
/// Locality permutation (v3 only): the internal→external id mapping of a
/// hub-first reordered shard. Skipped by readers that predate it, like
/// `MIDQ` — but *never* written to v1/v2 frames, where an old reader
/// would silently serve the reordered tables under internal ids.
pub(crate) const TAG_PERM: &[u8; 4] = b"PERM";
pub(crate) const TAG_HIGH: &[u8; 4] = b"HIGH";
pub(crate) const TAG_SEGDIR: &[u8; 4] = b"SEGD";

/// Upper bound on shards in one bundle (bounds the section count a file
/// may declare: `2 + 5 × MAX_SHARDS`).
pub const MAX_SHARDS: usize = 256;

/// An opened `.phnsw` artifact: every component a [`PhnswSearcher`] needs.
pub struct IndexBundle {
    /// Frozen CSR graph.
    pub graph: Arc<HnswGraph>,
    /// Trained PCA projection.
    pub pca: Arc<PcaModel>,
    /// Low-dim filter store (codec as saved — SQ8 on the default path).
    pub low: Arc<dyn VectorStore>,
    /// Mid-stage cascade table (`MIDQ`, v3 mid-stage builds only): SQ8
    /// quantization of the high-dim rows, scored between the PCA filter
    /// and the f32 rerank by `Staged`-tier requests.
    pub mid: Option<Arc<dyn VectorStore>>,
    /// High-dim f32 rerank table.
    pub high: Arc<VectorSet>,
    /// Locality permutation (`PERM`, v3 reordered builds only): the
    /// graph/low/mid/high tables are stored hub-first and row `i` holds
    /// the row externally known as `perm.ext(i)`. `None` = corpus order.
    pub perm: Option<Arc<Permutation>>,
}

fn write_section(w: &mut impl Write, tag: &[u8; 4], payload: &[u8]) -> Result<()> {
    w.write_all(tag)?;
    w.write_all(&(payload.len() as u64).to_le_bytes())?;
    w.write_all(payload)?;
    Ok(())
}

/// Stream the HIGH section without materializing a second copy of the
/// corpus: its length is exactly `12 + n·dim·4`, so the section frame can
/// be written up front and the f32 rows encoded through a small chunk
/// buffer.
fn write_high_section(w: &mut impl Write, high: &VectorSet) -> Result<()> {
    w.write_all(TAG_HIGH)?;
    let len = 12u64 + high.flat().len() as u64 * 4;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(&(high.dim() as u32).to_le_bytes())?;
    w.write_all(&(high.len() as u64).to_le_bytes())?;
    let mut chunk: Vec<u8> = Vec::with_capacity(CHUNK);
    for &x in high.flat() {
        chunk.extend_from_slice(&x.to_le_bytes());
        if chunk.len() >= CHUNK {
            w.write_all(&chunk)?;
            chunk.clear();
        }
    }
    w.write_all(&chunk)?;
    Ok(())
}

/// Staging-buffer size for the streamed HIGH section.
const CHUNK: usize = 64 * 1024;

fn decode_high(bytes: &[u8]) -> Result<VectorSet> {
    ensure!(bytes.len() >= 12, "HIGH section too short");
    let dim = u32::from_le_bytes(bytes[0..4].try_into()?) as usize;
    let n = u64::from_le_bytes(bytes[4..12].try_into()?);
    ensure!(dim >= 1 && dim <= 1 << 20, "implausible HIGH section dim {dim}");
    // Checked arithmetic: a crafted n must fail validation, not wrap.
    let want = n
        .checked_mul(dim as u64 * 4)
        .and_then(|p| p.checked_add(12))
        .unwrap_or(u64::MAX);
    ensure!(
        bytes.len() as u64 == want,
        "HIGH section length {} != expected {want}",
        bytes.len()
    );
    let mut data = Vec::with_capacity((n as usize) * dim);
    for c in bytes[12..].chunks_exact(4) {
        data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
    }
    Ok(VectorSet::from_flat(dim, data))
}

impl IndexBundle {
    /// Write a `.phnsw` artifact assembling the four components.
    pub fn save(
        path: impl AsRef<Path>,
        graph: &HnswGraph,
        pca: &PcaModel,
        low: &dyn VectorStore,
        high: &VectorSet,
    ) -> Result<()> {
        let path = path.as_ref();
        let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
        let mut w = BufWriter::new(f);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION_SINGLE.to_le_bytes())?;
        w.write_all(&4u32.to_le_bytes())?;
        // GRPH/PCAM/LOWQ are buffered (a few bytes per edge / component —
        // small next to the corpus); HIGH, the dominant section, streams
        // straight from the corpus so save never holds a second f32 copy.
        let mut graph_bytes = Vec::new();
        serialize::write_to(graph, &mut graph_bytes)?;
        write_section(&mut w, TAG_GRAPH, &graph_bytes)?;
        drop(graph_bytes);
        write_section(&mut w, TAG_PCA, &pca.to_bytes())?;
        write_section(&mut w, TAG_LOW, &low.to_bytes())?;
        write_high_section(&mut w, high)?;
        w.flush()?;
        Ok(())
    }

    /// Construct a ready-to-serve searcher from the opened components —
    /// no PCA refit, no re-projection, no re-quantization. A `MIDQ`
    /// section rides along as the searcher's mid-stage cascade table.
    pub fn searcher(&self, params: PhnswParams) -> PhnswSearcher {
        PhnswSearcher::with_stores_perm(
            self.graph.clone(),
            self.high.clone(),
            self.low.clone(),
            self.mid.clone(),
            self.perm.clone(),
            self.pca.clone(),
            params,
        )
    }
}

/// One decoded bundle section (shared by the v1/v2 streaming reader and
/// the v3 mapped reader in `super::v3`).
pub(crate) enum Section {
    Graph(HnswGraph),
    Pca(PcaModel),
    Low(Arc<dyn VectorStore>),
    /// Mid-stage cascade table (v3 `MIDQ`; never produced by v1/v2).
    Mid(Arc<dyn VectorStore>),
    /// Locality permutation (v3 `PERM`; never produced by v1/v2).
    Perm(Permutation),
    High(VectorSet),
    SegDir(ShardMap),
}

/// Read, length-validate, and decode every section of a `.phnsw` file.
/// Each raw payload is decoded (and dropped) before the next section is
/// read, so peak memory is the decoded index plus one section's bytes.
fn read_sections(path: &Path) -> Result<(u32, Vec<Section>)> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let file_len = f.metadata().with_context(|| format!("stat {}", path.display()))?.len();
    let mut r = BufReader::new(f);

    let mut head = [0u8; 12];
    r.read_exact(&mut head).context("bundle header")?;
    ensure!(&head[0..4] == MAGIC, "bad bundle magic {:?}", &head[0..4]);
    let version = u32::from_le_bytes(head[4..8].try_into()?);
    ensure!(
        version == VERSION_SINGLE || version == VERSION_SEGMENTED,
        "unsupported bundle version {version}"
    );
    let n_sections = u32::from_le_bytes(head[8..12].try_into()?);
    ensure!(n_sections as usize <= 2 + 5 * MAX_SHARDS, "implausible section count {n_sections}");

    let mut consumed = 12u64;
    let mut out = Vec::with_capacity(n_sections as usize);
    for _ in 0..n_sections {
        let mut tag = [0u8; 4];
        r.read_exact(&mut tag).context("section tag")?;
        let mut lenb = [0u8; 8];
        r.read_exact(&mut lenb).context("section length")?;
        let len = u64::from_le_bytes(lenb);
        consumed += 12;
        ensure!(
            len <= file_len.saturating_sub(consumed),
            "section {:?} declares {len} bytes but only {} remain",
            tag,
            file_len.saturating_sub(consumed)
        );
        let mut payload = vec![0u8; len as usize];
        r.read_exact(&mut payload)
            .with_context(|| format!("section {:?} payload", tag))?;
        consumed += len;
        match &tag {
            TAG_GRAPH => {
                out.push(Section::Graph(serialize::read_from(&mut payload.as_slice(), len)?))
            }
            TAG_PCA => out.push(Section::Pca(PcaModel::from_bytes(&payload)?)),
            TAG_LOW => out.push(Section::Low(store_from_bytes(&payload)?)),
            TAG_HIGH => out.push(Section::High(decode_high(&payload)?)),
            TAG_SEGDIR => out.push(Section::SegDir(decode_segdir(&payload)?)),
            // Unknown tags are skipped: newer writers may append
            // sections old readers do not understand.
            _ => {}
        }
    }
    Ok((version, out))
}

/// The shard directory (`SEGD` payload): `[u32 n_shards][u8 assignment]
/// [u64 n_total]`.
pub(crate) fn encode_segdir(map: &ShardMap) -> Vec<u8> {
    let mut out = Vec::with_capacity(13);
    out.extend_from_slice(&(map.n_shards() as u32).to_le_bytes());
    out.push(map.assignment().code());
    out.extend_from_slice(&(map.n_total() as u64).to_le_bytes());
    out
}

pub(crate) fn decode_segdir(bytes: &[u8]) -> Result<ShardMap> {
    ensure!(bytes.len() == 13, "SEGD section length {} != 13", bytes.len());
    let n_shards = u32::from_le_bytes(bytes[0..4].try_into()?) as usize;
    ensure!(n_shards >= 1 && n_shards <= MAX_SHARDS, "implausible shard count {n_shards}");
    let assignment = ShardAssignment::from_code(bytes[4])?;
    let n_total = u64::from_le_bytes(bytes[5..13].try_into()?);
    ensure!(n_total <= u32::MAX as u64, "implausible corpus size {n_total}");
    Ok(ShardMap::new(assignment, n_total as usize, n_shards))
}

/// An opened `.phnsw` file of either flavor. [`Bundle::open`] is *the*
/// way to open an artifact — one entry point, every version (1/2/3),
/// residency chosen by [`OpenOptions`].
pub enum Bundle {
    /// One monolithic index (the PR-2 layout).
    Single(IndexBundle),
    /// A sharded index: `SEGD` directory + one section group per shard.
    Segmented(SegmentedIndex),
}

impl Bundle {
    /// Open a `.phnsw` artifact of any version (1, 2, or 3). A v3 file
    /// opens through the page-aligned directory (zero-copy when
    /// `opts` requests mmap); v1/v2 files decode through the owned
    /// streaming path. Single vs segmented is dispatched on the `SEGD`
    /// directory section.
    pub fn open(path: impl AsRef<Path>, opts: OpenOptions) -> Result<Self> {
        let path = path.as_ref();
        // Version sniff from the 8-byte prefix; malformed headers fall
        // through to the legacy reader for its error messages.
        let version = sniff_version(path);
        if version == Some(VERSION_V3) {
            return super::v3::open_v3(path, opts.mmap);
        }
        if opts.mmap {
            let v = version.map_or_else(|| "unrecognized".to_string(), |v| format!("v{v}"));
            bail!(
                "--mmap serving requires a v3 page-aligned bundle, but {} is {v}; \
                 rebuild it with `phnsw build --bundle-format v3`",
                path.display()
            );
        }
        let (version, sections) = read_sections(path)?;
        let segdir = sections.iter().find_map(|s| match s {
            Section::SegDir(map) => Some(*map),
            _ => None,
        });
        if version == VERSION_SINGLE {
            // A v1 file with a directory would be misread by v1-only readers
            // (they skip the unknown tag); no writer produces one.
            ensure!(segdir.is_none(), "v1 bundle unexpectedly carries a segment directory");
            Ok(Bundle::Single(assemble_single(sections)?))
        } else {
            let Some(map) = segdir else {
                bail!("segmented (v2) bundle is missing its SEGD directory");
            };
            Ok(Bundle::Segmented(assemble_segmented(sections, map)?))
        }
    }

    /// Unwrap a monolithic bundle; fails loudly on a segmented one (its
    /// shards have no single graph/store to hand out — serve it through
    /// [`Bundle::engine`] instead).
    pub fn into_single(self) -> Result<IndexBundle> {
        match self {
            Bundle::Single(b) => Ok(b),
            Bundle::Segmented(s) => bail!(
                "bundle is segmented ({} shards); serve it through Bundle::engine",
                s.n_segments()
            ),
        }
    }

    /// Total indexed rows.
    pub fn len(&self) -> usize {
        match self {
            Bundle::Single(b) => b.high.len(),
            Bundle::Segmented(s) => s.len(),
        }
    }

    /// True if the bundle indexes no rows.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// High-dim query dimensionality.
    pub fn dim(&self) -> usize {
        match self {
            Bundle::Single(b) => b.high.dim(),
            Bundle::Segmented(s) => s.dim(),
        }
    }

    /// Number of segments (1 for a monolithic bundle).
    pub fn n_segments(&self) -> usize {
        match self {
            Bundle::Single(_) => 1,
            Bundle::Segmented(s) => s.n_segments(),
        }
    }

    /// Low-dim filter codec label (segmented: shard 0's codec).
    pub fn low_codec_label(&self) -> &'static str {
        match self {
            Bundle::Single(b) => b.low.codec().label(),
            Bundle::Segmented(s) => {
                s.segments.first().map(|seg| seg.low.codec().label()).unwrap_or("-")
            }
        }
    }

    /// Row `global` of the high-dim corpus the bundle indexes (the f32
    /// rerank table). For a segmented bundle the global id is remapped
    /// through the shard directory; for a locality-reordered bundle the
    /// shard-local id is further remapped through the `PERM` mapping, so
    /// callers always address corpus-order ids. Lets callers compute
    /// exact ground truth against a bundle — e.g. the serve CLI's
    /// filtered-recall gate — without re-generating the corpus.
    pub fn high_row(&self, global: usize) -> &[f32] {
        match self {
            Bundle::Single(b) => {
                let row = b.perm.as_ref().map_or(global, |p| p.int(global as u32) as usize);
                b.high.row(row)
            }
            Bundle::Segmented(s) => {
                let (shard, local) = s.map.shard_of(global as u32);
                let seg = &s.segments[shard];
                let row = seg.perm.as_ref().map_or(local as usize, |p| p.int(local) as usize);
                seg.high.row(row)
            }
        }
    }

    /// Ready-to-serve engine over the opened components: a plain
    /// [`PhnswSearcher`] for a monolithic bundle, a fan-out/merge
    /// [`crate::segment::SegmentedEngine`] for a sharded one.
    pub fn engine(&self, params: PhnswParams) -> Arc<dyn AnnEngine> {
        match self {
            Bundle::Single(b) => Arc::new(b.searcher(params)),
            Bundle::Segmented(s) => Arc::new(s.engine(params)),
        }
    }
}

/// How to open a `.phnsw` artifact. `Default` is the owned in-RAM
/// decode; builder methods opt into alternatives:
///
/// ```no_run
/// # use phnsw::runtime::{Bundle, OpenOptions};
/// let b = Bundle::open("index.phnsw", OpenOptions::new().mmap(true))?;
/// # anyhow::Ok(())
/// ```
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OpenOptions {
    /// Serve GRPH/LOWQ/HIGH directly from a memory mapping of the file
    /// (v3 layouts only): O(header) cold start, the f32 rerank table
    /// demand-paged from disk. Requesting this on a v1/v2 file is a
    /// loud error — rebuild with `phnsw build --bundle-format v3`.
    pub mmap: bool,
}

impl OpenOptions {
    /// Default options (owned in-RAM decode).
    pub fn new() -> Self {
        Self::default()
    }

    /// Request zero-copy mmap serving (v3 bundles only).
    pub fn mmap(mut self, mmap: bool) -> Self {
        self.mmap = mmap;
        self
    }
}

/// Best-effort version sniff from the 8-byte file prefix; `None` when
/// the file is unreadable or does not carry the bundle magic.
fn sniff_version(path: &Path) -> Option<u32> {
    let mut head = [0u8; 8];
    let mut f = std::fs::File::open(path).ok()?;
    f.read_exact(&mut head).ok()?;
    (&head[0..4] == MAGIC).then(|| u32::from_le_bytes(head[4..8].try_into().unwrap()))
}

/// Assemble the classic single-segment bundle from its sections.
pub(crate) fn assemble_single(sections: Vec<Section>) -> Result<IndexBundle> {
    let mut graph = None;
    let mut pca = None;
    let mut low: Option<Arc<dyn VectorStore>> = None;
    let mut mid: Option<Arc<dyn VectorStore>> = None;
    let mut perm: Option<Permutation> = None;
    let mut high = None;
    for section in sections {
        match section {
            Section::Graph(g) => graph = Some(g),
            Section::Pca(p) => pca = Some(p),
            Section::Low(l) => low = Some(l),
            Section::Mid(m) => mid = Some(m),
            Section::Perm(p) => perm = Some(p),
            Section::High(h) => high = Some(h),
            Section::SegDir(_) => {}
        }
    }
    let (Some(graph), Some(pca), Some(low), Some(high)) = (graph, pca, low, high) else {
        bail!("bundle is missing a required section (GRPH/PCAM/LOWQ/HIGH)");
    };
    ensure!(graph.len() == high.len(), "graph/high-dim size mismatch");
    ensure!(graph.len() == low.len(), "graph/low-dim size mismatch");
    ensure!(pca.dim() == high.dim(), "PCA input dim != high-dim table dim");
    ensure!(pca.k() == low.dim(), "PCA output dim != low-dim store dim");
    if let Some(m) = &mid {
        ensure!(m.len() == high.len(), "mid/high-dim size mismatch");
        ensure!(m.dim() == high.dim(), "MIDQ dim != high-dim table dim");
    }
    if let Some(p) = &perm {
        ensure!(p.len() == high.len(), "PERM/high-dim size mismatch");
    }
    Ok(IndexBundle {
        graph: Arc::new(graph),
        pca: Arc::new(pca),
        low,
        mid,
        high: Arc::new(high),
        // An identity mapping carries no information; drop it so the
        // searcher skips translation entirely.
        perm: perm.filter(|p| !p.is_identity()).map(Arc::new),
    })
}

/// Assemble a segmented index: pair the repeated `GRPH`/`LOWQ`/`HIGH`
/// groups positionally (file order is shard order) and validate every
/// shard against the directory and the shared PCA model.
pub(crate) fn assemble_segmented(sections: Vec<Section>, map: ShardMap) -> Result<SegmentedIndex> {
    let mut pca = None;
    let mut graphs = Vec::new();
    let mut lows: Vec<Arc<dyn VectorStore>> = Vec::new();
    let mut mids: Vec<Arc<dyn VectorStore>> = Vec::new();
    let mut perms: Vec<Permutation> = Vec::new();
    let mut highs = Vec::new();
    for section in sections {
        match section {
            Section::Graph(g) => graphs.push(g),
            Section::Pca(p) => pca = Some(p),
            Section::Low(l) => lows.push(l),
            Section::Mid(m) => mids.push(m),
            Section::Perm(p) => perms.push(p),
            Section::High(h) => highs.push(h),
            Section::SegDir(_) => {}
        }
    }
    let Some(pca) = pca else {
        bail!("segmented bundle is missing the PCAM section");
    };
    let s = map.n_shards();
    ensure!(
        graphs.len() == s && lows.len() == s && highs.len() == s,
        "segmented bundle declares {s} shards but holds {} GRPH / {} LOWQ / {} HIGH sections",
        graphs.len(),
        lows.len(),
        highs.len()
    );
    // MIDQ is all-or-nothing: a bundle with mid tables for only some
    // shards would make the cascade tier shard-dependent.
    ensure!(
        mids.is_empty() || mids.len() == s,
        "segmented bundle holds {} MIDQ sections for {s} shards (must be 0 or {s})",
        mids.len()
    );
    let mids: Vec<Option<Arc<dyn VectorStore>>> = if mids.is_empty() {
        vec![None; s]
    } else {
        mids.into_iter().map(Some).collect()
    };
    // PERM is all-or-nothing too: the writer emits an identity mapping
    // for any shard the reorder pass left untouched, so the positional
    // pairing of repeated section groups stays unambiguous.
    ensure!(
        perms.is_empty() || perms.len() == s,
        "segmented bundle holds {} PERM sections for {s} shards (must be 0 or {s})",
        perms.len()
    );
    let perms: Vec<Option<Permutation>> = if perms.is_empty() {
        (0..s).map(|_| None).collect()
    } else {
        perms.into_iter().map(Some).collect()
    };
    let pca = Arc::new(pca);
    let mut segments = Vec::with_capacity(s);
    for (i, ((((graph, low), mid), perm), high)) in
        graphs.into_iter().zip(lows).zip(mids).zip(perms).zip(highs).enumerate()
    {
        ensure!(
            graph.len() == map.shard_len(i),
            "shard {i}: graph holds {} nodes, directory says {}",
            graph.len(),
            map.shard_len(i)
        );
        ensure!(graph.len() == high.len(), "shard {i}: graph/high-dim size mismatch");
        ensure!(graph.len() == low.len(), "shard {i}: graph/low-dim size mismatch");
        ensure!(pca.dim() == high.dim(), "shard {i}: PCA input dim != high-dim table dim");
        ensure!(pca.k() == low.dim(), "shard {i}: PCA output dim != low-dim store dim");
        if let Some(m) = &mid {
            ensure!(m.len() == high.len(), "shard {i}: mid/high-dim size mismatch");
            ensure!(m.dim() == high.dim(), "shard {i}: MIDQ dim != high-dim table dim");
        }
        if let Some(p) = &perm {
            ensure!(p.len() == high.len(), "shard {i}: PERM/high-dim size mismatch");
        }
        segments.push(Segment {
            graph: Arc::new(graph),
            high: Arc::new(high),
            low,
            mid,
            perm: perm.filter(|p| !p.is_identity()).map(Arc::new),
        });
    }
    Ok(SegmentedIndex { pca, segments, map })
}

/// One section row of [`BundleInfo`] — where a section's payload lives
/// in the file, for `phnsw inspect`.
#[derive(Debug, Clone)]
pub struct SectionInfo {
    /// Four-character section tag (e.g. `GRPH`).
    pub tag: String,
    /// Absolute byte offset of the payload in the file.
    pub offset: u64,
    /// Payload length in bytes.
    pub len: u64,
    /// True when the payload starts on a page (4096-byte) boundary —
    /// the zero-copy requirement; always false for v1/v2 framed files.
    pub page_aligned: bool,
}

/// What `phnsw inspect --bundle` prints: the section directory of a
/// `.phnsw` file of any version, read without decoding any payload
/// (only the 13-byte `SEGD` directory is parsed, for the shard count).
#[derive(Debug, Clone)]
pub struct BundleInfo {
    /// Bundle format version (1, 2, or 3).
    pub version: u32,
    /// `"single"` or `"segmented"`.
    pub flavor: &'static str,
    /// Shard count (1 for a single-segment bundle).
    pub n_shards: usize,
    /// Total file length in bytes.
    pub file_len: u64,
    /// Every section in file order (unknown tags included).
    pub sections: Vec<SectionInfo>,
    /// Locality-reorder summary: `None` for legacy / corpus-order
    /// bundles (`reorder: none`), `Some` when `PERM` sections are
    /// present.
    pub perm: Option<PermInfo>,
}

/// What `inspect` reports about a bundle's `PERM` sections.
#[derive(Debug, Clone)]
pub struct PermInfo {
    /// Number of `PERM` sections (one per shard in a reordered bundle).
    pub n_sections: usize,
    /// Total mapping entries across all `PERM` sections (= corpus rows).
    pub entries: u64,
    /// True when every `PERM` payload starts on a page boundary.
    pub page_aligned: bool,
}

/// Read a `.phnsw` file's section directory without decoding payloads —
/// the `phnsw inspect` entry point and a loud v3-vs-v1/v2 discriminator.
pub fn inspect_bundle(path: impl AsRef<Path>) -> Result<BundleInfo> {
    use std::io::{Seek, SeekFrom};
    let path = path.as_ref();
    if sniff_version(path) == Some(VERSION_V3) {
        return super::v3::inspect_v3(path);
    }
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let file_len = f.metadata().with_context(|| format!("stat {}", path.display()))?.len();
    let mut r = BufReader::new(f);
    let mut head = [0u8; 12];
    r.read_exact(&mut head).context("bundle header")?;
    ensure!(&head[0..4] == MAGIC, "bad bundle magic {:?}", &head[0..4]);
    let version = u32::from_le_bytes(head[4..8].try_into()?);
    ensure!(
        version == VERSION_SINGLE || version == VERSION_SEGMENTED,
        "unsupported bundle version {version}"
    );
    let n_sections = u32::from_le_bytes(head[8..12].try_into()?);
    ensure!(n_sections as usize <= 2 + 5 * MAX_SHARDS, "implausible section count {n_sections}");
    let mut consumed = 12u64;
    let mut sections = Vec::with_capacity(n_sections as usize);
    let mut n_shards = 1usize;
    for _ in 0..n_sections {
        let mut tag = [0u8; 4];
        r.read_exact(&mut tag).context("section tag")?;
        let mut lenb = [0u8; 8];
        r.read_exact(&mut lenb).context("section length")?;
        let len = u64::from_le_bytes(lenb);
        consumed += 12;
        ensure!(
            len <= file_len.saturating_sub(consumed),
            "section {:?} declares {len} bytes but only {} remain",
            tag,
            file_len.saturating_sub(consumed)
        );
        if &tag == TAG_SEGDIR {
            let mut payload = vec![0u8; len as usize];
            r.read_exact(&mut payload).context("SEGD payload")?;
            n_shards = decode_segdir(&payload)?.n_shards();
        } else {
            r.seek(SeekFrom::Current(len as i64)).context("skip section payload")?;
        }
        sections.push(SectionInfo {
            tag: String::from_utf8_lossy(&tag).into_owned(),
            offset: consumed,
            len,
            page_aligned: consumed % 4096 == 0,
        });
        consumed += len;
    }
    Ok(BundleInfo {
        version,
        flavor: if version == VERSION_SEGMENTED { "segmented" } else { "single" },
        n_shards,
        file_len,
        sections,
        // v1/v2 writers refuse reordered indexes, so legacy bundles are
        // always corpus-order.
        perm: None,
    })
}

/// Write a segmented index as one `.phnsw` artifact. An `S = 1` index is
/// written in the classic single-segment layout (no `SEGD`), so it stays
/// readable by [`Bundle::into_single`] and byte-compatible with PR-2
/// writers; `S > 1` leads with the shard directory and the shared PCA,
/// then one `GRPH`/`LOWQ`/`HIGH` group per shard in shard order.
pub fn save_segmented(path: impl AsRef<Path>, index: &SegmentedIndex) -> Result<()> {
    let s = index.n_segments();
    ensure!(s >= 1, "index holds no segments");
    ensure!(s <= MAX_SHARDS, "{s} shards exceeds the bundle cap {MAX_SHARDS}");
    // No PERM frame exists in v1/v2, and a reader that merely skipped an
    // unknown tag would serve the reordered tables under internal ids —
    // silently wrong results. Refuse loudly instead.
    ensure!(
        index.segments.iter().all(|seg| seg.perm.is_none()),
        "locality-reordered indexes require the v3 bundle format (PERM section); \
         write with --bundle-format v3 or rebuild with --reorder none"
    );
    if s == 1 {
        let seg = &index.segments[0];
        return IndexBundle::save(path, &seg.graph, &index.pca, seg.low.as_ref(), &seg.high);
    }
    let path = path.as_ref();
    let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION_SEGMENTED.to_le_bytes())?;
    w.write_all(&((2 + 3 * s) as u32).to_le_bytes())?;
    write_section(&mut w, TAG_SEGDIR, &encode_segdir(&index.map))?;
    write_section(&mut w, TAG_PCA, &index.pca.to_bytes())?;
    for seg in &index.segments {
        let mut graph_bytes = Vec::new();
        serialize::write_to(&seg.graph, &mut graph_bytes)?;
        write_section(&mut w, TAG_GRAPH, &graph_bytes)?;
        drop(graph_bytes);
        write_section(&mut w, TAG_LOW, &seg.low.to_bytes())?;
        write_high_section(&mut w, &seg.high)?;
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic::{generate, SyntheticConfig};
    use crate::graph::build::{build, BuildConfig};
    use crate::search::AnnEngine;
    use crate::store::Sq8Store;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("phnsw_bundle_{}_{name}", std::process::id()));
        p
    }

    /// The one-way-to-open path, unwrapped to a single-segment bundle.
    fn open_single(p: &std::path::Path) -> Result<IndexBundle> {
        Bundle::open(p, OpenOptions::default())?.into_single()
    }

    struct Stack {
        base: VectorSet,
        queries: VectorSet,
        graph: HnswGraph,
        pca: PcaModel,
        low: Sq8Store,
    }

    fn stack(n: usize) -> Stack {
        let cfg = SyntheticConfig { n_base: n, n_queries: 20, ..SyntheticConfig::tiny() };
        let (base, queries) = generate(&cfg);
        let graph = build(&base, &BuildConfig { m: 8, ef_construction: 48, ..Default::default() });
        let pca = PcaModel::fit(&base, 8, 7);
        let low = Sq8Store::from_set(&pca.project_set(&base));
        Stack { base, queries, graph, pca, low }
    }

    #[test]
    fn roundtrip_is_bitwise_identical() {
        let s = stack(800);
        let p = tmp("roundtrip.phnsw");
        IndexBundle::save(&p, &s.graph, &s.pca, &s.low, &s.base).unwrap();
        let b = open_single(&p).unwrap();

        let native = PhnswSearcher::with_store(
            Arc::new(s.graph.clone()),
            Arc::new(s.base.clone()),
            Arc::new(s.low.clone()),
            Arc::new(s.pca.clone()),
            PhnswParams::default(),
        );
        let booted = b.searcher(PhnswParams::default());
        for q in s.queries.iter() {
            assert_eq!(native.search(q), booted.search(q), "bundle boot must be bitwise identical");
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn open_rejects_truncation_and_corruption() {
        let s = stack(300);
        let p = tmp("corrupt.phnsw");
        IndexBundle::save(&p, &s.graph, &s.pca, &s.low, &s.base).unwrap();
        let bytes = std::fs::read(&p).unwrap();

        // Truncated mid-section.
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        assert!(open_single(&p).is_err(), "truncated bundle must fail");

        // Bad magic.
        let mut bad = bytes.clone();
        bad[0..4].copy_from_slice(b"XXXX");
        std::fs::write(&p, &bad).unwrap();
        assert!(open_single(&p).is_err());

        // Section length blown up far past the file: must be rejected by
        // the remaining-bytes bound, not attempted as an allocation.
        let mut bad = bytes.clone();
        // First section header sits right after the 12-byte file header.
        bad[16..24].copy_from_slice(&(u64::MAX / 2).to_le_bytes());
        std::fs::write(&p, &bad).unwrap();
        assert!(open_single(&p).is_err());

        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn open_rejects_missing_section() {
        // A file with only the header and zero sections parses the frame
        // but fails the completeness check.
        let p = tmp("empty.phnsw");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"PHNB");
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&0u32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let err = open_single(&p).unwrap_err();
        assert!(err.to_string().contains("missing"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn open_rejects_cross_component_mismatch() {
        // Swap in a low store of the wrong population: sizes must be
        // cross-checked at open time, before a searcher is built.
        let s = stack(300);
        let small = stack(100);
        let p = tmp("mismatch.phnsw");
        IndexBundle::save(&p, &s.graph, &s.pca, &small.low, &s.base).unwrap();
        assert!(open_single(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn open_bundle_dispatches_single_vs_segmented() {
        use crate::segment::{build_segmented, SegmentSpec};
        // Single-segment file → Single.
        let s = stack(300);
        let p = tmp("dispatch_single.phnsw");
        IndexBundle::save(&p, &s.graph, &s.pca, &s.low, &s.base).unwrap();
        let any = Bundle::open(&p, OpenOptions::default()).unwrap();
        assert!(matches!(any, Bundle::Single(_)));
        assert_eq!(any.n_segments(), 1);
        assert_eq!(any.len(), 300);
        std::fs::remove_file(&p).ok();

        // Segmented file → Segmented, with the directory honored.
        let cfg = SyntheticConfig { n_base: 400, n_queries: 1, ..SyntheticConfig::tiny() };
        let (base, _) = generate(&cfg);
        let bc = BuildConfig { m: 4, ef_construction: 16, ..Default::default() };
        let idx = build_segmented(&base, &bc, 6, 7, &SegmentSpec::new(3, 2));
        let p = tmp("dispatch_seg.phnsw");
        super::save_segmented(&p, &idx).unwrap();
        // Segmented files must declare version 2 so pre-segmentation
        // readers reject them loudly instead of serving the last shard.
        let header = std::fs::read(&p).unwrap();
        assert_eq!(u32::from_le_bytes(header[4..8].try_into().unwrap()), 2);
        let any = Bundle::open(&p, OpenOptions::default()).unwrap();
        assert_eq!(any.n_segments(), 3);
        assert_eq!(any.len(), 400);
        assert_eq!(any.low_codec_label(), "sq8");
        // Unwrapping to a single-segment bundle refuses segmented files
        // loudly.
        let err = Bundle::open(&p, OpenOptions::default())
            .unwrap()
            .into_single()
            .unwrap_err();
        assert!(err.to_string().contains("segmented"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn save_segmented_with_one_shard_writes_the_classic_layout() {
        use crate::segment::{build_segmented, SegmentSpec};
        let cfg = SyntheticConfig { n_base: 250, n_queries: 1, ..SyntheticConfig::tiny() };
        let (base, _) = generate(&cfg);
        let bc = BuildConfig { m: 4, ef_construction: 16, ..Default::default() };
        let idx = build_segmented(&base, &bc, 6, 7, &SegmentSpec::new(1, 1));
        let p = tmp("seg_as_classic.phnsw");
        super::save_segmented(&p, &idx).unwrap();
        // Readable by the classic single-segment opener: no SEGD section.
        let b = open_single(&p).unwrap();
        assert_eq!(b.high.len(), 250);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn segmented_open_rejects_directory_mismatch() {
        use crate::segment::{build_segmented, SegmentSpec};
        let cfg = SyntheticConfig { n_base: 300, n_queries: 1, ..SyntheticConfig::tiny() };
        let (base, _) = generate(&cfg);
        let bc = BuildConfig { m: 4, ef_construction: 16, ..Default::default() };
        let idx = build_segmented(&base, &bc, 6, 7, &SegmentSpec::new(3, 2));
        let p = tmp("seg_badder.phnsw");
        super::save_segmented(&p, &idx).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        // Corrupt the SEGD shard count (first section payload, offset
        // 12-byte file header + 12-byte section header).
        let mut bad = bytes.clone();
        bad[24..28].copy_from_slice(&9u32.to_le_bytes());
        std::fs::write(&p, &bad).unwrap();
        assert!(
            Bundle::open(&p, OpenOptions::default()).is_err(),
            "shard-count mismatch must be rejected"
        );
        // Truncation mid-shard is rejected too.
        std::fs::write(&p, &bytes[..bytes.len() * 2 / 3]).unwrap();
        assert!(Bundle::open(&p, OpenOptions::default()).is_err());
        std::fs::remove_file(&p).ok();
    }
}
