//! Distance kernels for the rust hot path.
//!
//! `l2_sq` is the workhorse: 8-wide unrolled squared-L2 with four
//! independent accumulators so the compiler can keep FMA pipes busy and
//! auto-vectorize. The scalar reference lives in
//! [`crate::dataset::l2_sq_scalar`]; equivalence is tested below and
//! property-tested in `rust/tests/properties.rs`.

/// Squared Euclidean distance.
///
/// Lane-coherent 8-wide accumulator: each SIMD lane keeps its own partial
/// sum (`acc[j] += d[j]²`), which LLVM maps 1:1 onto AVX2/AVX-512 FMA
/// lanes (a cross-lane pattern like `s0 += d0² + d4²` defeats the
/// vectorizer — measured 7× slower, see EXPERIMENTS.md §Perf).
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0f32; 8];
    let ac = a.chunks_exact(8);
    let bc = b.chunks_exact(8);
    let (atail, btail) = (ac.remainder(), bc.remainder());
    for (ca, cb) in ac.zip(bc) {
        for j in 0..8 {
            let d = ca[j] - cb[j];
            acc[j] = d.mul_add(d, acc[j]);
        }
    }
    let mut tail = 0f32;
    for (x, y) in atail.iter().zip(btail) {
        let d = x - y;
        tail += d * d;
    }
    let s = ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    s + tail
}

/// Batched distances: query against `k` contiguous rows of `block`
/// (row-major `k × dim`). Mirrors the 16-lane `Dist.L` unit: the caller
/// hands one packed neighbor block (DB layout ③) and receives all lane
/// distances. Results are written into `out[..k]`.
#[inline]
pub fn l2_sq_batch(query: &[f32], block: &[f32], dim: usize, out: &mut [f32]) {
    debug_assert_eq!(block.len() % dim, 0);
    let k = block.len() / dim;
    debug_assert!(out.len() >= k);
    for (lane, row) in block.chunks_exact(dim).enumerate() {
        out[lane] = l2_sq(query, row);
    }
}

/// Inner-product form of squared L2: `‖a‖² + ‖b‖² − 2·a·b`. This is the
/// MXU-friendly decomposition the Pallas `dist_h` kernel uses for large
/// candidate tiles; exposed here so tests can check both formulations agree.
#[inline]
pub fn l2_sq_via_dot(a: &[f32], b: &[f32], norm_a_sq: f32, norm_b_sq: f32) -> f32 {
    let mut dot = 0f32;
    for i in 0..a.len() {
        dot += a[i] * b[i];
    }
    (norm_a_sq + norm_b_sq - 2.0 * dot).max(0.0)
}

/// Squared norm helper for the dot formulation.
#[inline]
pub fn norm_sq(a: &[f32]) -> f32 {
    let mut s = 0f32;
    for &x in a {
        s += x * x;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::l2_sq_scalar;
    use crate::rng::Pcg32;

    #[test]
    fn matches_scalar_reference_across_lengths() {
        let mut rng = Pcg32::new(1);
        for n in [0usize, 1, 2, 7, 8, 9, 15, 16, 17, 31, 64, 127, 128, 250] {
            let a: Vec<f32> = (0..n).map(|_| rng.gaussian()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.gaussian()).collect();
            let fast = l2_sq(&a, &b);
            let slow = l2_sq_scalar(&a, &b);
            assert!(
                (fast - slow).abs() <= 1e-4 * slow.max(1.0),
                "n={n}: {fast} vs {slow}"
            );
        }
    }

    #[test]
    fn batch_matches_individual() {
        let mut rng = Pcg32::new(2);
        let dim = 15;
        let k = 16;
        let q: Vec<f32> = (0..dim).map(|_| rng.gaussian()).collect();
        let block: Vec<f32> = (0..k * dim).map(|_| rng.gaussian()).collect();
        let mut out = vec![0f32; k];
        l2_sq_batch(&q, &block, dim, &mut out);
        for lane in 0..k {
            let row = &block[lane * dim..(lane + 1) * dim];
            assert_eq!(out[lane], l2_sq(&q, row));
        }
    }

    #[test]
    fn dot_formulation_agrees() {
        let mut rng = Pcg32::new(3);
        for _ in 0..50 {
            let a: Vec<f32> = (0..128).map(|_| 255.0 * rng.f32()).collect();
            let b: Vec<f32> = (0..128).map(|_| 255.0 * rng.f32()).collect();
            let direct = l2_sq(&a, &b);
            let viadot = l2_sq_via_dot(&a, &b, norm_sq(&a), norm_sq(&b));
            // The dot formulation is less accurate on large-magnitude data;
            // allow relative 1e-3 (same tolerance the pallas test uses).
            assert!(
                (direct - viadot).abs() <= 1e-3 * direct.max(1.0),
                "{direct} vs {viadot}"
            );
        }
    }

    #[test]
    fn zero_length_distance_is_zero() {
        assert_eq!(l2_sq(&[], &[]), 0.0);
    }

    #[test]
    fn triangle_inequality_on_sqrt() {
        let mut rng = Pcg32::new(4);
        for _ in 0..100 {
            let a: Vec<f32> = (0..33).map(|_| rng.gaussian()).collect();
            let b: Vec<f32> = (0..33).map(|_| rng.gaussian()).collect();
            let c: Vec<f32> = (0..33).map(|_| rng.gaussian()).collect();
            let ab = l2_sq(&a, &b).sqrt();
            let bc = l2_sq(&b, &c).sqrt();
            let ac = l2_sq(&a, &c).sqrt();
            assert!(ac <= ab + bc + 1e-4);
        }
    }
}
