//! Standard HNSW search (Algorithm 2 + 5 of [2]) — the HNSW-CPU /
//! HNSW-Std baseline. Every unvisited neighbor of an expanded node costs
//! one *high-dimensional* distance computation and one high-dim raw-data
//! fetch: exactly the traffic pHNSW's low-dim filter removes.

use super::beam::{beam_search_layer, BeamSpec, HighDimScorer};
use super::config::SearchParams;
use super::dist::l2_sq;
use super::request::SearchRequest;
use super::stats::{SearchStats, SearchTrace};
use super::visited::VisitedSet;
use super::{AnnEngine, Neighbor};
use crate::dataset::VectorSet;
use crate::graph::HnswGraph;
use std::sync::{Arc, Mutex};

/// Reusable per-query scratch (pooled so `search(&self)` stays lock-cheap).
struct Scratch {
    visited: VisitedSet,
}

/// Standard HNSW searcher over a built graph.
pub struct HnswSearcher {
    graph: Arc<HnswGraph>,
    data: Arc<VectorSet>,
    params: SearchParams,
    pool: Mutex<Vec<Scratch>>,
}

impl HnswSearcher {
    /// Create a searcher. `data` must be the corpus the graph was built on.
    pub fn new(graph: Arc<HnswGraph>, data: Arc<VectorSet>, params: SearchParams) -> Self {
        assert_eq!(graph.len(), data.len(), "graph/corpus size mismatch");
        Self { graph, data, params, pool: Mutex::new(Vec::new()) }
    }

    /// The search parameters in use.
    pub fn params(&self) -> &SearchParams {
        &self.params
    }

    fn take_scratch(&self) -> Scratch {
        self.pool
            .lock()
            .unwrap()
            .pop()
            .unwrap_or_else(|| Scratch { visited: VisitedSet::new(self.data.len()) })
    }

    fn put_scratch(&self, s: Scratch) {
        self.pool.lock().unwrap().push(s);
    }

    /// Beam search at one layer; `entry` must be sorted ascending.
    /// Returns up to `spec.ef` nearest, ascending. Delegates to the
    /// shared beam core with the plain high-dim scorer.
    fn search_layer(
        &self,
        q: &[f32],
        entry: &[(f32, u32)],
        spec: BeamSpec<'_>,
        layer: usize,
        visited: &mut VisitedSet,
        trace: Option<&mut SearchTrace>,
    ) -> Vec<(f32, u32)> {
        let mut scorer = HighDimScorer::new(q, &self.data);
        beam_search_layer(&self.graph, &mut scorer, entry, spec, layer, visited, trace)
    }

    /// Full multi-layer search for one request, optionally tracing.
    /// Per-request beam widths resolve via
    /// [`SearchRequest::effective_search`]; the filter applies at layer 0
    /// only (upper layers just produce entry points). Default knobs are
    /// bitwise identical to the pre-request search path.
    pub fn search_request_traced(
        &self,
        req: &SearchRequest<'_>,
        mut trace: Option<&mut SearchTrace>,
    ) -> Vec<Neighbor> {
        let q = req.vector;
        assert_eq!(q.len(), self.data.dim(), "query dimensionality mismatch");
        if self.graph.is_empty() {
            return Vec::new();
        }
        let filter = req.filter.as_deref();
        let mut eff = req.effective_search(&self.params);
        // Upper clamp (shared rationale with pHNSW): client-supplied
        // widths must not size allocations beyond the corpus.
        let n = self.data.len().max(1);
        eff.ef_upper = eff.ef_upper.min(n);
        eff.ef_l0 = eff.ef_l0.min(n);
        // Degenerate filters short-circuit before the walk (shared with
        // pHNSW — see `search::filtered_shortcut`).
        if let Some(out) = super::filtered_shortcut(
            filter,
            &self.data,
            q,
            eff.ef(0),
            req.topk,
            trace.as_deref_mut(),
        ) {
            return out;
        }
        let mut scratch = self.take_scratch();
        let ep = self.graph.entry_point();
        // Warm the entry point's top-layer adjacency while its seed
        // distance computes — the walk's very first pointer chase.
        self.graph.prefetch_neighbors(ep, self.graph.max_level());
        let mut entry = vec![(l2_sq(q, self.data.row(ep as usize)), ep)];
        for layer in (1..=self.graph.max_level()).rev() {
            entry = self.search_layer(
                q,
                &entry,
                BeamSpec::unfiltered(eff.ef(layer)),
                layer,
                &mut scratch.visited,
                trace.as_deref_mut(),
            );
        }
        let found = self.search_layer(
            q,
            &entry,
            BeamSpec { ef: eff.ef(0), filter },
            0,
            &mut scratch.visited,
            trace.as_deref_mut(),
        );
        self.put_scratch(scratch);
        let mut out: Vec<Neighbor> =
            found.into_iter().map(|(dist, id)| Neighbor { id, dist }).collect();
        if let Some(k) = req.topk {
            out.truncate(k);
        }
        out
    }

    /// Full multi-layer search with default knobs, optionally tracing.
    pub fn search_traced(&self, q: &[f32], trace: Option<&mut SearchTrace>) -> Vec<Neighbor> {
        self.search_request_traced(&SearchRequest::new(q), trace)
    }

    /// Search and return the trace (used by the hw simulator).
    pub fn search_full_trace(&self, q: &[f32]) -> (Vec<Neighbor>, SearchTrace) {
        let mut t = SearchTrace::new();
        let r = self.search_traced(q, Some(&mut t));
        (r, t)
    }
}

impl AnnEngine for HnswSearcher {
    fn name(&self) -> &str {
        "hnsw"
    }

    fn search_req(&self, req: &SearchRequest) -> Vec<Neighbor> {
        self.search_request_traced(req, None)
    }

    fn search_req_with_stats(&self, req: &SearchRequest) -> (Vec<Neighbor>, SearchStats) {
        let mut t = SearchTrace::new();
        let r = self.search_request_traced(req, Some(&mut t));
        (r, t.stats())
    }

    fn search_batch_req(&self, reqs: &[SearchRequest]) -> Vec<Vec<Neighbor>> {
        super::parallel_search_batch_req(self, reqs)
    }

    fn search_batch_req_with_stats(
        &self,
        reqs: &[SearchRequest],
    ) -> (Vec<Vec<Neighbor>>, SearchStats) {
        super::parallel_search_batch_req_with_stats(self, reqs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic::{generate, SyntheticConfig};
    use crate::dataset::{ground_truth, VectorSet};
    use crate::graph::build::{build, BuildConfig};
    use crate::metrics::recall_at_k;

    fn setup(n: usize) -> (Arc<VectorSet>, VectorSet, Arc<HnswGraph>) {
        let cfg = SyntheticConfig { n_base: n, n_queries: 50, ..SyntheticConfig::tiny() };
        let (base, queries) = generate(&cfg);
        let g = build(&base, &BuildConfig { m: 8, ef_construction: 100, ..Default::default() });
        (Arc::new(base), queries, Arc::new(g))
    }

    #[test]
    fn finds_exact_match_for_base_vector_query() {
        let (base, _, g) = setup(1000);
        let s = HnswSearcher::new(g, base.clone(), SearchParams::default());
        for id in [0u32, 123, 999] {
            let res = s.search(base.row(id as usize));
            assert_eq!(res[0].id, id, "querying a base vector must return itself first");
            assert_eq!(res[0].dist, 0.0);
        }
    }

    #[test]
    fn results_sorted_and_unique() {
        let (base, queries, g) = setup(1000);
        let s = HnswSearcher::new(g, base, SearchParams::default());
        for q in queries.iter().take(10) {
            let res = s.search(q);
            assert_eq!(res.len(), 10);
            for w in res.windows(2) {
                assert!(w[0].dist <= w[1].dist, "not sorted");
            }
            let ids: std::collections::HashSet<_> = res.iter().map(|n| n.id).collect();
            assert_eq!(ids.len(), res.len(), "duplicate ids");
        }
    }

    #[test]
    fn recall_is_high_on_clustered_data() {
        let (base, queries, g) = setup(2000);
        let gt = ground_truth(&base, &queries, 10);
        let s = HnswSearcher::new(g, base, SearchParams { ef_upper: 1, ef_l0: 32 });
        let results: Vec<Vec<u32>> = queries
            .iter()
            .map(|q| s.search(q).into_iter().map(|n| n.id).collect())
            .collect();
        let r = recall_at_k(&results, &gt, 10);
        assert!(r > 0.85, "recall@10 = {r}");
    }

    #[test]
    fn trace_counters_are_consistent() {
        let (base, queries, g) = setup(1000);
        let s = HnswSearcher::new(g, base, SearchParams::default());
        let (_, t) = s.search_full_trace(queries.row(0));
        let st = t.stats();
        assert!(st.hops > 0);
        assert_eq!(st.lowdim_dists, 0, "plain HNSW computes no low-dim distances");
        assert_eq!(st.ksort_calls, 0);
        assert!(st.highdim_dists <= st.neighbors_fetched);
        assert!(st.visited_checks >= st.highdim_dists);
        assert!(st.hops_l0 <= st.hops);
    }

    #[test]
    fn stats_match_traced_run() {
        let (base, queries, g) = setup(500);
        let s = HnswSearcher::new(g, base, SearchParams::default());
        let (r1, st) = s.search_with_stats(queries.row(1));
        let (r2, t) = s.search_full_trace(queries.row(1));
        assert_eq!(r1, r2);
        assert_eq!(st, t.stats());
    }

    #[test]
    fn searcher_is_reusable_across_queries() {
        let (base, queries, g) = setup(500);
        let s = HnswSearcher::new(g, base, SearchParams::default());
        let first = s.search(queries.row(0));
        for _ in 0..5 {
            assert_eq!(s.search(queries.row(0)), first, "results must be deterministic");
        }
    }

    #[test]
    fn nan_query_does_not_panic() {
        // Regression for the MinDist NaN panic: partial_cmp().unwrap()
        // aborted the search thread on any NaN distance. total_cmp orders
        // NaN after every finite value instead.
        let (base, _, g) = setup(500);
        let s = HnswSearcher::new(g, base.clone(), SearchParams::default());
        let mut q = base.row(0).to_vec();
        q[3] = f32::NAN;
        let res = s.search(&q);
        assert!(res.len() <= s.params().ef(0), "NaN query returns without panicking");
        // The searcher must stay healthy for subsequent well-formed queries.
        let ok = s.search(base.row(1));
        assert_eq!(ok[0].id, 1);
    }

    #[test]
    fn search_batch_matches_sequential_bitwise() {
        let (base, queries, g) = setup(1000);
        let s = HnswSearcher::new(g, base, SearchParams::default());
        let qrefs: Vec<&[f32]> = (0..30).map(|i| queries.row(i)).collect();
        let sequential: Vec<Vec<Neighbor>> = qrefs.iter().map(|q| s.search(q)).collect();
        let batched = s.search_batch(&qrefs);
        assert_eq!(batched, sequential, "batched results must be bitwise identical");
        // Single-element and empty batches take the sequential path.
        assert_eq!(s.search_batch(&qrefs[..1]), sequential[..1].to_vec());
        assert!(s.search_batch(&[]).is_empty());
    }
}
