//! Segmented index layer — the unit of horizontal scale.
//!
//! One monolithic HNSW graph caps both build throughput (the builder is
//! inherently serial per graph) and dataset size (one memory arena, one
//! core). This layer splits a corpus into `S` shards by a deterministic
//! [`ShardMap`], builds an independent HNSW segment per shard **in
//! parallel** ([`build`] — each segment reuses the single-shard builder,
//! so per-shard results stay deterministic regardless of thread count),
//! and serves them through a [`SegmentedEngine`] that fans every query
//! (and whole batches) across shards and merges the per-shard top-k into
//! one global result — the partition-and-merge scheme SmartANNS-style
//! systems use to scale graph ANN beyond one core.
//!
//! All segments share a single [`crate::pca::PcaModel`] fitted on the
//! full corpus, so the filter space is globally consistent; quantization
//! (SQ8) is per-shard, matching the future per-shard codec-choice axis.
//!
//! Shard-local ids are what each segment's graph and stores speak;
//! [`ShardMap::global_of`] remaps them to corpus ids at the merge
//! boundary, so callers never observe shard-local numbering.

pub mod build;
pub mod engine;
pub mod live;
pub mod memtable;

pub use build::{build_segmented, build_segmented_with_pca, Segment, SegmentedIndex};
pub use engine::SegmentedEngine;
pub use live::{LiveConfig, LiveEngine, LiveStats};
pub use memtable::MemSegment;

/// How global row ids are distributed over shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardAssignment {
    /// Row `i` goes to shard `i % S` (default: spreads clustered inserts
    /// evenly regardless of corpus order).
    RoundRobin,
    /// Balanced contiguous ranges: the first `n % S` shards get
    /// `⌈n/S⌉` rows, the rest `⌊n/S⌋`.
    Contiguous,
}

impl ShardAssignment {
    /// Stable on-disk code (bundle `SEGD` section).
    pub fn code(&self) -> u8 {
        match self {
            ShardAssignment::RoundRobin => 0,
            ShardAssignment::Contiguous => 1,
        }
    }

    /// Inverse of [`Self::code`].
    pub fn from_code(c: u8) -> crate::Result<Self> {
        match c {
            0 => Ok(ShardAssignment::RoundRobin),
            1 => Ok(ShardAssignment::Contiguous),
            other => anyhow::bail!("unknown shard assignment code {other}"),
        }
    }

    /// Short display label (also the CLI spelling).
    pub fn label(&self) -> &'static str {
        match self {
            ShardAssignment::RoundRobin => "rr",
            ShardAssignment::Contiguous => "contig",
        }
    }

    /// Parse the CLI spelling.
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "rr" | "round-robin" => Ok(ShardAssignment::RoundRobin),
            "contig" | "contiguous" => Ok(ShardAssignment::Contiguous),
            other => anyhow::bail!("unknown shard assignment {other:?} (rr | contig)"),
        }
    }
}

/// Deterministic bijection between global row ids and (shard, local id)
/// pairs. Pure arithmetic — no lookup tables — so the mapping costs
/// nothing to store in a bundle and nothing to evaluate at merge time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardMap {
    assignment: ShardAssignment,
    n_total: usize,
    n_shards: usize,
}

impl ShardMap {
    /// Create a mapping of `n_total` rows onto `n_shards` shards.
    pub fn new(assignment: ShardAssignment, n_total: usize, n_shards: usize) -> Self {
        assert!(n_shards >= 1, "need at least one shard");
        Self { assignment, n_total, n_shards }
    }

    /// Number of shards.
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Total rows across all shards.
    pub fn n_total(&self) -> usize {
        self.n_total
    }

    /// The assignment scheme.
    pub fn assignment(&self) -> ShardAssignment {
        self.assignment
    }

    /// First global id of contiguous shard `s`.
    fn contiguous_start(&self, s: usize) -> usize {
        let base = self.n_total / self.n_shards;
        let rem = self.n_total % self.n_shards;
        s * base + s.min(rem)
    }

    /// Number of rows assigned to shard `s`.
    pub fn shard_len(&self, s: usize) -> usize {
        assert!(s < self.n_shards, "shard {s} out of range");
        match self.assignment {
            ShardAssignment::RoundRobin => (self.n_total + self.n_shards - 1 - s) / self.n_shards,
            ShardAssignment::Contiguous => {
                self.contiguous_start(s + 1).min(self.n_total) - self.contiguous_start(s)
            }
        }
    }

    /// Global id of local row `local` in shard `shard`.
    #[inline]
    pub fn global_of(&self, shard: usize, local: u32) -> u32 {
        debug_assert!(shard < self.n_shards);
        debug_assert!((local as usize) < self.shard_len(shard));
        match self.assignment {
            ShardAssignment::RoundRobin => local * self.n_shards as u32 + shard as u32,
            ShardAssignment::Contiguous => self.contiguous_start(shard) as u32 + local,
        }
    }

    /// Inverse of [`Self::global_of`]: which shard holds `global`, and at
    /// which local index.
    #[inline]
    pub fn shard_of(&self, global: u32) -> (usize, u32) {
        debug_assert!((global as usize) < self.n_total);
        match self.assignment {
            ShardAssignment::RoundRobin => (
                (global as usize) % self.n_shards,
                global / self.n_shards as u32,
            ),
            ShardAssignment::Contiguous => {
                let base = self.n_total / self.n_shards;
                let rem = self.n_total % self.n_shards;
                let g = global as usize;
                // Rows below rem*(base+1) live in the wide shards.
                let s = if g < rem * (base + 1) {
                    g / (base + 1)
                } else if base == 0 {
                    // n < S: every row landed in a wide shard above.
                    unreachable!("global {g} beyond populated shards")
                } else {
                    rem + (g - rem * (base + 1)) / base
                };
                (s, (g - self.contiguous_start(s)) as u32)
            }
        }
    }
}

/// How to segment a corpus: shard count, assignment scheme, and the
/// builder-thread budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SegmentSpec {
    /// Number of shards `S`.
    pub n_shards: usize,
    /// Global-id → shard mapping scheme.
    pub assignment: ShardAssignment,
    /// Max concurrently building shards (clamped to `n_shards`).
    pub build_threads: usize,
    /// Also fit the mid-stage cascade table per shard (SQ8 over the
    /// shard's *high*-dim rows — the v3 `MIDQ` section), enabling
    /// `Staged`-tier serving. Off by default: the table costs 1 B per
    /// high-dim component of bundle size and build-time corpus scans.
    pub mid_stage: bool,
    /// Locality relabeling applied per shard after the graph is built
    /// (hub-first node order; see [`crate::graph::reorder`]). The
    /// library default is `None` so programmatic builds stay bitwise
    /// pinned to corpus order; the CLI defaults to `hub-bfs`.
    pub reorder: crate::graph::ReorderMode,
}

impl Default for SegmentSpec {
    fn default() -> Self {
        Self {
            n_shards: 1,
            assignment: ShardAssignment::RoundRobin,
            build_threads: 1,
            mid_stage: false,
            reorder: crate::graph::ReorderMode::None,
        }
    }
}

impl SegmentSpec {
    /// Spec with `n_shards` shards built by `build_threads` threads.
    pub fn new(n_shards: usize, build_threads: usize) -> Self {
        Self { n_shards, build_threads, ..Self::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn maps(n: usize, s: usize) -> [ShardMap; 2] {
        [
            ShardMap::new(ShardAssignment::RoundRobin, n, s),
            ShardMap::new(ShardAssignment::Contiguous, n, s),
        ]
    }

    #[test]
    fn shard_lens_partition_the_corpus() {
        for (n, s) in [(10, 3), (7, 7), (3, 5), (0, 4), (1000, 16), (13, 1)] {
            for m in maps(n, s) {
                let total: usize = (0..s).map(|i| m.shard_len(i)).sum();
                assert_eq!(total, n, "{m:?}");
                // Balanced within one row.
                let lens: Vec<usize> = (0..s).map(|i| m.shard_len(i)).collect();
                let (mn, mx) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
                assert!(mx - mn <= 1, "{m:?}: {lens:?}");
            }
        }
    }

    #[test]
    fn global_of_is_a_bijection() {
        for (n, s) in [(10, 3), (3, 5), (100, 7), (16, 16)] {
            for m in maps(n, s) {
                let mut seen = vec![false; n];
                for shard in 0..s {
                    for local in 0..m.shard_len(shard) as u32 {
                        let g = m.global_of(shard, local) as usize;
                        assert!(g < n, "{m:?}");
                        assert!(!seen[g], "{m:?}: duplicate global {g}");
                        seen[g] = true;
                    }
                }
                assert!(seen.iter().all(|&x| x), "{m:?}: unmapped globals");
            }
        }
    }

    #[test]
    fn shard_of_inverts_global_of() {
        for (n, s) in [(10, 3), (3, 5), (101, 8), (64, 1)] {
            for m in maps(n, s) {
                for g in 0..n as u32 {
                    let (shard, local) = m.shard_of(g);
                    assert_eq!(m.global_of(shard, local), g, "{m:?} global {g}");
                }
            }
        }
    }

    #[test]
    fn round_robin_interleaves_and_contiguous_ranges() {
        let rr = ShardMap::new(ShardAssignment::RoundRobin, 10, 3);
        assert_eq!(rr.global_of(0, 0), 0);
        assert_eq!(rr.global_of(1, 0), 1);
        assert_eq!(rr.global_of(0, 1), 3);
        let c = ShardMap::new(ShardAssignment::Contiguous, 10, 3);
        // 10 over 3 → lens 4, 3, 3; starts 0, 4, 7.
        assert_eq!(c.shard_len(0), 4);
        assert_eq!(c.shard_len(1), 3);
        assert_eq!(c.global_of(1, 0), 4);
        assert_eq!(c.global_of(2, 2), 9);
    }

    #[test]
    fn assignment_codes_roundtrip() {
        for a in [ShardAssignment::RoundRobin, ShardAssignment::Contiguous] {
            assert_eq!(ShardAssignment::from_code(a.code()).unwrap(), a);
            assert_eq!(ShardAssignment::parse(a.label()).unwrap(), a);
        }
        assert!(ShardAssignment::from_code(9).is_err());
        assert!(ShardAssignment::parse("zig").is_err());
    }
}
