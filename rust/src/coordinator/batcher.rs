//! Dynamic batcher: operations accumulate until either `max_batch` is
//! reached or the oldest enqueued op has waited `max_wait` — the
//! standard latency/throughput trade-off knob of serving systems.
//! The server runs two instances: one feeding the multi-worker search
//! pool and one feeding the single ingest worker, which keeps ingest
//! ops in submission order across batches.

use super::{Op, QueryResult};
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Batcher tuning.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Maximum queries per batch.
    pub max_batch: usize,
    /// Maximum time the oldest query may wait before a partial batch is
    /// released.
    pub max_wait: Duration,
    /// Bound on queued items (backpressure); `enqueue` fails beyond it.
    pub queue_cap: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 32, max_wait: Duration::from_micros(200), queue_cap: 4096 }
    }
}

/// One enqueued operation plus its response channel and arrival time.
pub struct Pending {
    /// The operation (search or ingest).
    pub op: Op,
    /// Where the worker sends the result.
    pub reply: std::sync::mpsc::Sender<QueryResult>,
    /// Arrival timestamp (latency accounting).
    pub arrived: Instant,
}

struct Inner {
    queue: VecDeque<Pending>,
    closed: bool,
}

/// Thread-safe dynamic batcher.
pub struct Batcher {
    cfg: BatcherConfig,
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl Batcher {
    /// New batcher with the given tuning.
    pub fn new(cfg: BatcherConfig) -> Self {
        Self { cfg, inner: Mutex::new(Inner { queue: VecDeque::new(), closed: false }), cv: Condvar::new() }
    }

    /// Configured tuning.
    pub fn config(&self) -> &BatcherConfig {
        &self.cfg
    }

    /// Enqueue an operation; fails when the queue is full (backpressure)
    /// or the batcher is shut down.
    pub fn enqueue(&self, p: Pending) -> Result<(), Pending> {
        let mut g = self.inner.lock().unwrap();
        if g.closed || g.queue.len() >= self.cfg.queue_cap {
            return Err(p);
        }
        g.queue.push_back(p);
        self.cv.notify_one();
        Ok(())
    }

    /// Current queue depth.
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    /// Block until a batch is ready (size or deadline trigger); `None`
    /// after shutdown once the queue drains.
    pub fn next_batch(&self) -> Option<Vec<Pending>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.queue.len() >= self.cfg.max_batch {
                break;
            }
            if !g.queue.is_empty() {
                let oldest = g.queue.front().unwrap().arrived;
                let age = oldest.elapsed();
                if age >= self.cfg.max_wait {
                    break;
                }
                let (ng, _timeout) = self
                    .cv
                    .wait_timeout(g, self.cfg.max_wait - age)
                    .unwrap();
                g = ng;
                continue;
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
        let take = g.queue.len().min(self.cfg.max_batch);
        Some(g.queue.drain(..take).collect())
    }

    /// Shut down: wake all waiters; queued items still drain.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::sync::Arc;

    fn pending(v: f32) -> (Pending, mpsc::Receiver<QueryResult>) {
        let (tx, rx) = mpsc::channel();
        (
            Pending {
                op: Op::Search(crate::coordinator::Query::new(vec![v])),
                reply: tx,
                arrived: Instant::now(),
            },
            rx,
        )
    }

    #[test]
    fn size_trigger_releases_full_batch() {
        let b = Batcher::new(BatcherConfig { max_batch: 4, max_wait: Duration::from_secs(10), queue_cap: 100 });
        for i in 0..4 {
            b.enqueue(pending(i as f32).0).map_err(|_| ()).unwrap();
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn deadline_trigger_releases_partial_batch() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 100,
            max_wait: Duration::from_millis(5),
            queue_cap: 100,
        });
        b.enqueue(pending(1.0).0).map_err(|_| ()).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(4), "released too early");
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let b = Batcher::new(BatcherConfig { max_batch: 8, max_wait: Duration::from_secs(1), queue_cap: 2 });
        assert!(b.enqueue(pending(1.0).0).is_ok());
        assert!(b.enqueue(pending(2.0).0).is_ok());
        assert!(b.enqueue(pending(3.0).0).is_err(), "third enqueue must bounce");
    }

    #[test]
    fn close_wakes_blocked_worker() {
        let b = Arc::new(Batcher::new(BatcherConfig::default()));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        b.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn close_rejects_new_but_drains_queued() {
        let b = Batcher::new(BatcherConfig { max_batch: 10, max_wait: Duration::from_millis(1), queue_cap: 10 });
        b.enqueue(pending(1.0).0).map_err(|_| ()).unwrap();
        b.close();
        assert!(b.enqueue(pending(2.0).0).is_err());
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn fifo_order_within_batch() {
        let b = Batcher::new(BatcherConfig { max_batch: 3, max_wait: Duration::from_secs(10), queue_cap: 10 });
        for i in 0..3 {
            b.enqueue(pending(i as f32).0).map_err(|_| ()).unwrap();
        }
        let batch = b.next_batch().unwrap();
        let vals: Vec<f32> =
            batch.iter().map(|p| p.op.as_search().unwrap().core.vector[0]).collect();
        assert_eq!(vals, vec![0.0, 1.0, 2.0]);
    }
}
