//! pHNSW search — Algorithm 1 of the paper.
//!
//! Per expanded node, *all* neighbors are scored in the PCA-reduced
//! low-dimensional space (`Dist.L`), a top-k filter keeps the best k
//! (`kSort.L`), and only those k survivors get a high-dimensional distance
//! (`Dist.H`) and result-list update. The filter size k varies per layer
//! (the paper's hierarchical-k contribution, §III-B).
//!
//! The low-dim filter table lives behind a [`VectorStore`] codec
//! (default: SQ8 scalar quantization, 1 byte/component). Each hop gathers
//! the adjacency list's vectors into one contiguous block and scores the
//! whole list through a batched kernel — the software analog of the
//! paper's inline neighbor block (DB layout ③) streaming through the
//! 16-lane Dist.L unit — never one `row()` + `l2_sq` per neighbor. The
//! high-dim rerank stays full-precision f32, so codec error perturbs only
//! the filter *ordering*, exactly the regime the paper's Algorithm 1
//! tolerates.
//!
//! Interpretation notes (the listing leaves two details implicit):
//! * `C_pca_tmp` is reset at each hop — it collects the survivors that the
//!   high-dim check *admitted* during this hop, and its furthest low-dim
//!   distance becomes the next hop's `f_pca` prune threshold (lines 5/24).
//!   Only that scalar is carried between hops (survivors arrive sorted
//!   ascending from `TopK::into_sorted`, so the threshold is the last
//!   admitted element — no per-hop fold over a saved list). An empty
//!   survivor set yields an infinite threshold, which is safe (no pruning).
//! * The visited check happens *after* the top-k filter (line 16), exactly
//!   as listed: already-visited nodes may occupy filter slots. This is the
//!   faithful behaviour and is what the hardware's dataflow (§IV-C step 5)
//!   implements.

use super::beam::{beam_search_layer, BeamSpec, BeamState, HopCounters, NeighborScorer};
use super::config::PhnswParams;
use super::dist::l2_sq;
use super::request::{IdFilter, QualityTier, SearchRequest};
use super::stats::{SearchStats, SearchTrace};
use super::visited::VisitedSet;
use super::{AnnEngine, Neighbor};
use crate::dataset::gt::TopK;
use crate::dataset::VectorSet;
use crate::graph::{HnswGraph, Permutation};
use crate::pca::PcaModel;
use crate::store::{Sq8Store, StoreScratch, VectorStore};
use std::sync::{Arc, Mutex};

/// Per-query scratch state, pooled across queries.
struct Scratch {
    visited: VisitedSet,
    /// Projected query (PCA space, f32).
    q_pca: Vec<f32>,
    /// Store-side scratch: codec-domain query + gather block.
    store: StoreScratch,
    /// Mid-stage (MIDQ) scratch: high-dim codec query + gather block.
    mid_store: StoreScratch,
    /// Per-hop batched filter distances (one slot per neighbor).
    dists: Vec<f32>,
}

/// pHNSW searcher: graph + high-dim corpus + PCA model + low-dim filter
/// store (codec-quantized).
pub struct PhnswSearcher {
    graph: Arc<HnswGraph>,
    data_high: Arc<VectorSet>,
    /// The low-dim filter table (layout ③/④ payload) behind its codec.
    low: Arc<dyn VectorStore>,
    /// Optional mid-stage table: SQ8 over the *high*-dimensional vectors
    /// (the MIDQ bundle section). `None` disables the staged cascade —
    /// `Staged`-tier requests silently degrade to `Exact`.
    mid: Option<Arc<dyn VectorStore>>,
    pca: Arc<PcaModel>,
    params: PhnswParams,
    /// Locality relabeling of every table above (see
    /// [`crate::graph::reorder`]): internal row `i` holds the row the
    /// caller knows as `perm.ext(i)`. Requests arrive and results leave
    /// in external ids; the walk itself runs entirely in internal ids.
    /// `None` (corpus order) skips translation bit-for-bit.
    perm: Option<Arc<Permutation>>,
    pool: Mutex<Vec<Scratch>>,
}

/// Algorithm 1's per-hop scoring, plugged into the shared beam core:
/// low-dim filter over *all* neighbors (Dist.L, lines 9–13) through one
/// gathered-block kernel call, top-k selection (kSort.L), then high-dim
/// rerank of the ≤ k survivors (Dist.H, lines 14–23). The visited check
/// happens *after* the filter (line 16), exactly as listed.
///
/// Crate-visible so the live memtable can run the genuine Algorithm 1
/// loop over its staging graph under a read lock (it cannot use
/// [`PhnswSearcher`], whose `Arc`-owned stores assume frozen data).
pub(crate) struct PcaFilterScorer<'a> {
    /// Query, original space.
    pub(crate) q: &'a [f32],
    pub(crate) data_high: &'a VectorSet,
    /// Low-dim filter store (scored via its batched kernel).
    pub(crate) low: &'a dyn VectorStore,
    /// Codec-domain query + gather block, prepared once per search.
    pub(crate) store_scratch: &'a mut StoreScratch,
    /// Batched filter distances for the current hop.
    pub(crate) dists: &'a mut Vec<f32>,
    /// Filter size at the current layer (set per layer by the caller).
    pub(crate) k: usize,
    /// f_pca prune threshold (line 5): the furthest low-dim distance among
    /// the survivors the high-dim check admitted during the previous hop.
    /// ∞ when no survivor was admitted (no pruning), which is safe.
    pub(crate) f_pca: f32,
    /// Mid-stage (MIDQ) table: SQ8 over the high-dim vectors. `None`
    /// runs the exact two-stage path, bitwise identical to pre-cascade.
    pub(crate) mid: Option<&'a dyn VectorStore>,
    /// Mid-stage scratch (codec-domain high-dim query, prepared once per
    /// search by the caller when `mid` is set).
    pub(crate) mid_scratch: &'a mut StoreScratch,
    /// Fraction of filter survivors promoted to the f32 rerank when the
    /// mid stage is active; clamped to [0, 1] by the caller.
    pub(crate) rerank_frac: f32,
}

impl NeighborScorer for PcaFilterScorer<'_> {
    fn begin_layer(&mut self) {
        self.f_pca = f32::INFINITY;
    }

    fn expand(
        &mut self,
        nbrs: &[u32],
        visited: &mut VisitedSet,
        beam: &mut BeamState<'_>,
    ) -> HopCounters {
        // Step 2 (lines 9–13): low-dim filter over all neighbors — one
        // gather + one batched kernel pass for the whole adjacency list.
        if self.dists.len() < nbrs.len() {
            self.dists.resize(nbrs.len(), 0.0);
        }
        self.low.score_block(self.store_scratch, nbrs, &mut self.dists[..nbrs.len()]);
        let mut cpca = TopK::new(self.k); // top-k smallest low-dim distances
        for (lane, &e) in nbrs.iter().enumerate() {
            let d_low = self.dists[lane];
            if d_low < self.f_pca {
                cpca.offer(d_low, e);
            }
        }
        let mut survivors = cpca.into_sorted();
        // Mid stage (Staged tier only): score every survivor against the
        // SQ8 mid table in one batched pass and promote only the best
        // `rerank_frac` fraction (minimum one) to the f32 rerank. Kept
        // survivors stay in ascending-d_low order so the f_pca threshold
        // semantics below are unchanged — the mid stage only shrinks the
        // set that pays a full-width f32 row.
        let mut mid_count = 0u32;
        if let Some(mid) = self.mid {
            let n = survivors.len();
            let keep = ((n as f32 * self.rerank_frac).ceil() as usize).clamp(1, n);
            if keep < n {
                mid_count = n as u32;
                let ids: Vec<u32> = survivors.iter().map(|&(_, m)| m).collect();
                let mut mid_dists = vec![0f32; n];
                mid.score_block(self.mid_scratch, &ids, &mut mid_dists);
                // Rank survivor slots by mid distance (id tie-break keeps
                // the cascade deterministic), keep the best, then restore
                // slot order — slots were ascending by d_low.
                let mut order: Vec<usize> = (0..n).collect();
                order.sort_unstable_by(|&a, &b| {
                    mid_dists[a]
                        .total_cmp(&mid_dists[b])
                        .then_with(|| survivors[a].1.cmp(&survivors[b].1))
                });
                order.truncate(keep);
                order.sort_unstable();
                survivors = order.into_iter().map(|i| survivors[i]).collect();
            }
        }
        // The ≤ k survivor rows are id-scattered across the high-dim
        // table; hint them now so the rerank loop's gathers land warm
        // (the hardware prefetcher sees no pattern in filter output).
        for &(_, m) in &survivors {
            crate::prefetch::prefetch_slice(self.data_high.row(m as usize));
        }

        // Step 3 (lines 14–23): high-dim rerank of the ≤ k survivors.
        // Survivors arrive ascending by d_low, so the last *admitted* one
        // carries the next hop's f_pca threshold (line 24) — tracked as a
        // scalar instead of re-deriving it from a saved C_pca list.
        let mut next_f_pca = f32::INFINITY;
        let mut any_admitted = false;
        let mut highdim = 0u32;
        for &(d_low, m) in &survivors {
            if visited.insert(m) {
                // lines 18–19
                let d_m = l2_sq(self.q, self.data_high.row(m as usize));
                highdim += 1;
                // lines 20–23: C ∪ m, F ∪ m (+ RMF) via the shared rule.
                if beam.admit(d_m, m) {
                    next_f_pca = d_low; // line 20: m joins C_pca_tmp
                    any_admitted = true;
                }
            }
        }
        // line 24: C_pca ← C_pca_tmp; only its furthest distance matters.
        self.f_pca = if any_admitted { next_f_pca } else { f32::INFINITY };

        HopCounters {
            lowdim: nbrs.len() as u32,
            ksort: 1,
            highdim,
            mid: mid_count,
            visited_checks: survivors.len() as u32,
        }
    }
}

impl PhnswSearcher {
    /// Create a searcher over an explicit low-dim store (any codec).
    ///
    /// `low` must hold the PCA projection of `data_high` under its codec;
    /// dimensional consistency is asserted here, value consistency is the
    /// caller's contract (see [`Self::new`] for the checked f32 path).
    pub fn with_store(
        graph: Arc<HnswGraph>,
        data_high: Arc<VectorSet>,
        low: Arc<dyn VectorStore>,
        pca: Arc<PcaModel>,
        params: PhnswParams,
    ) -> Self {
        Self::with_stores(graph, data_high, low, None, pca, params)
    }

    /// Create a searcher over an explicit low-dim store plus an optional
    /// mid-stage store (SQ8 quantization of the *high*-dim vectors, the
    /// MIDQ bundle section). With `mid: None` the staged cascade is
    /// unavailable and every request runs the exact two-stage path.
    pub fn with_stores(
        graph: Arc<HnswGraph>,
        data_high: Arc<VectorSet>,
        low: Arc<dyn VectorStore>,
        mid: Option<Arc<dyn VectorStore>>,
        pca: Arc<PcaModel>,
        params: PhnswParams,
    ) -> Self {
        Self::with_stores_perm(graph, data_high, low, mid, None, pca, params)
    }

    /// [`Self::with_stores`] over locality-reordered tables: `perm`
    /// declares that graph/high/low/mid all share the reordered row
    /// labeling, and the searcher translates ids at its boundary —
    /// filters arrive external, results leave external. `None` is the
    /// plain corpus-order path, bit-for-bit.
    pub fn with_stores_perm(
        graph: Arc<HnswGraph>,
        data_high: Arc<VectorSet>,
        low: Arc<dyn VectorStore>,
        mid: Option<Arc<dyn VectorStore>>,
        perm: Option<Arc<Permutation>>,
        pca: Arc<PcaModel>,
        params: PhnswParams,
    ) -> Self {
        assert_eq!(graph.len(), data_high.len(), "graph/corpus size mismatch");
        assert_eq!(data_high.len(), low.len(), "high/low corpus size mismatch");
        assert_eq!(pca.dim(), data_high.dim(), "PCA input dim mismatch");
        assert_eq!(pca.k(), low.dim(), "PCA output dim mismatch");
        if let Some(m) = &mid {
            assert_eq!(data_high.len(), m.len(), "high/mid corpus size mismatch");
            assert_eq!(data_high.dim(), m.dim(), "mid store dim mismatch");
        }
        if let Some(p) = &perm {
            assert_eq!(p.len(), graph.len(), "permutation/corpus size mismatch");
        }
        params.validate().expect("invalid pHNSW params");
        Self { graph, data_high, low, mid, pca, params, perm, pool: Mutex::new(Vec::new()) }
    }

    /// Create a searcher from an f32 projection table. `data_low` must be
    /// `pca.project_set(data_high)` (checked probabilistically); it is
    /// then quantized into the default SQ8 filter store.
    pub fn new(
        graph: Arc<HnswGraph>,
        data_high: Arc<VectorSet>,
        data_low: Arc<VectorSet>,
        pca: Arc<PcaModel>,
        params: PhnswParams,
    ) -> Self {
        assert_eq!(data_high.len(), data_low.len(), "high/low corpus size mismatch");
        assert_eq!(pca.k(), data_low.dim(), "PCA output dim mismatch");
        // Spot-check that data_low really is the projection of data_high.
        if !data_high.is_empty() {
            assert_eq!(pca.dim(), data_high.dim(), "PCA input dim mismatch");
            let mut buf = vec![0f32; pca.k()];
            for &probe in &[0usize, data_high.len() / 2, data_high.len() - 1] {
                pca.project(data_high.row(probe), &mut buf);
                let err = l2_sq(&buf, data_low.row(probe));
                assert!(
                    err < 1e-3 * (1.0 + l2_sq(&buf, &vec![0.0; pca.k()])),
                    "data_low row {probe} is not the PCA projection of data_high"
                );
            }
        }
        let low: Arc<dyn VectorStore> = Arc::new(Sq8Store::from_set(&data_low));
        Self::with_store(graph, data_high, low, pca, params)
    }

    /// Convenience constructor: fit PCA, project the corpus, and quantize
    /// the filter table (SQ8) internally.
    pub fn build_from(
        graph: Arc<HnswGraph>,
        data_high: Arc<VectorSet>,
        dim_low: usize,
        params: PhnswParams,
        seed: u64,
    ) -> Self {
        let pca = Arc::new(PcaModel::fit(&data_high, dim_low, seed));
        let data_low = Arc::new(pca.project_set(&data_high));
        Self::new(graph, data_high, data_low, pca, params)
    }

    /// The filter parameters in use.
    pub fn params(&self) -> &PhnswParams {
        &self.params
    }

    /// The PCA model (shared with the AOT kernel path).
    pub fn pca(&self) -> &Arc<PcaModel> {
        &self.pca
    }

    /// The low-dim filter store (codec-quantized projected corpus).
    pub fn low_store(&self) -> &Arc<dyn VectorStore> {
        &self.low
    }

    /// The mid-stage store (SQ8 over the high-dim corpus), when present.
    pub fn mid_store(&self) -> Option<&Arc<dyn VectorStore>> {
        self.mid.as_ref()
    }

    /// The locality permutation the tables were reordered under, when
    /// present (`None` = corpus order).
    pub fn perm(&self) -> Option<&Arc<Permutation>> {
        self.perm.as_ref()
    }

    fn take_scratch(&self) -> Scratch {
        self.pool.lock().unwrap().pop().unwrap_or_else(|| Scratch {
            visited: VisitedSet::new(self.data_high.len()),
            q_pca: vec![0f32; self.pca.k()],
            store: StoreScratch::new(),
            mid_store: StoreScratch::new(),
            dists: vec![0f32; self.graph.m0() + 1],
        })
    }

    fn put_scratch(&self, s: Scratch) {
        self.pool.lock().unwrap().push(s);
    }

    /// Full multi-layer pHNSW search for one request, optionally tracing.
    ///
    /// Per-request knobs resolve here: beam widths come from
    /// [`SearchRequest::effective_search`] over the engine's configured
    /// params (so `topk` floors the layer-0 beam and a filter's
    /// selectivity boosts it), and the filter rides into the layer-0 beam
    /// as a result-side predicate. Upper layers search unfiltered — they
    /// only produce entry points, and starving the descent at `ef_upper`
    /// = 1 would strand the walk. A default-knob request is bitwise
    /// identical to the pre-request search path.
    pub fn search_request_traced(
        &self,
        req: &SearchRequest<'_>,
        mut trace: Option<&mut SearchTrace>,
    ) -> Vec<Neighbor> {
        let q = req.vector;
        assert_eq!(q.len(), self.data_high.dim(), "query dimensionality mismatch");
        if self.graph.is_empty() {
            return Vec::new();
        }
        let ext_filter = req.filter.as_deref();
        // Reordered tables: rewrite the external-id filter into internal
        // (relabeled) space once per request — the walk, the shortcut,
        // and the beam predicate all speak internal ids from here on.
        // n_total/n_allowed are preserved, so the selectivity-driven ef
        // boost in `effective_search` is untouched. A filter sized for a
        // different corpus is passed through untranslated so the
        // shortcut's mismatch degrade still fires.
        let translated: Option<IdFilter> = match (&self.perm, ext_filter) {
            (Some(p), Some(f)) if f.n_total() == self.data_high.len() => {
                Some(IdFilter::from_fn(f.n_total(), |int| f.allows(p.ext(int))))
            }
            _ => None,
        };
        let filter = translated.as_ref().or(ext_filter);
        let mut eff = req.effective_search(&self.params.search);
        // Upper clamp: beam widths beyond the corpus size cannot improve
        // results but would size the result heap from a client-supplied
        // number — a hostile topk/ef override must not drive allocation.
        let n = self.data_high.len().max(1);
        eff.ef_upper = eff.ef_upper.min(n);
        eff.ef_l0 = eff.ef_l0.min(n);
        // Degenerate filters short-circuit before the walk: mismatched
        // or empty filters degrade to empty results, small allowed
        // subsets are scored exactly (see `search::filtered_shortcut`).
        if let Some(mut out) = super::filtered_shortcut(
            filter,
            &self.data_high,
            q,
            eff.ef(0),
            req.topk,
            trace.as_deref_mut(),
        ) {
            if let Some(p) = &self.perm {
                for nb in &mut out {
                    nb.id = p.ext(nb.id);
                }
            }
            return out;
        }
        // Resolve the cascade tier: `Staged` engages the mid stage only
        // when a mid table exists and the fraction actually prunes —
        // everything else (including a `Staged` request against an
        // engine without MIDQ) runs the exact path, bitwise identical to
        // pre-cascade behavior.
        let (mid_ref, rerank_frac) = match req.tier {
            QualityTier::Staged { rerank_frac } if self.mid.is_some() => {
                let f = if rerank_frac.is_finite() { rerank_frac.clamp(0.0, 1.0) } else { 1.0 };
                if f < 1.0 {
                    (self.mid.as_deref(), f)
                } else {
                    (None, 1.0)
                }
            }
            _ => (None, 1.0),
        };
        let mut scratch = self.take_scratch();
        // Step 1 (Fig. 1(c)): project the query once, then transform it
        // into the store's codec domain (both transforms are per-query,
        // not per-hop).
        let mut q_pca = std::mem::take(&mut scratch.q_pca);
        self.pca.project(q, &mut q_pca);
        let mut store_scratch = std::mem::take(&mut scratch.store);
        self.low.prepare_query(&q_pca, &mut store_scratch);
        let mut mid_scratch = std::mem::take(&mut scratch.mid_store);
        if let Some(m) = mid_ref {
            m.prepare_query(q, &mut mid_scratch);
        }
        let mut dists = std::mem::take(&mut scratch.dists);

        let mut scorer = PcaFilterScorer {
            q,
            data_high: &self.data_high,
            low: self.low.as_ref(),
            store_scratch: &mut store_scratch,
            dists: &mut dists,
            k: self.params.k(0),
            f_pca: f32::INFINITY,
            mid: mid_ref,
            mid_scratch: &mut mid_scratch,
            rerank_frac,
        };
        let ep = self.graph.entry_point();
        // Warm the entry point's top-layer adjacency while its seed
        // distance computes — the walk's very first pointer chase.
        self.graph.prefetch_neighbors(ep, self.graph.max_level());
        let mut entry = vec![(l2_sq(q, self.data_high.row(ep as usize)), ep)];
        for layer in (1..=self.graph.max_level()).rev() {
            scorer.k = self.params.k(layer);
            entry = beam_search_layer(
                &self.graph,
                &mut scorer,
                &entry,
                BeamSpec::unfiltered(eff.ef(layer)),
                layer,
                &mut scratch.visited,
                trace.as_deref_mut(),
            );
        }
        scorer.k = self.params.k(0);
        let found = beam_search_layer(
            &self.graph,
            &mut scorer,
            &entry,
            BeamSpec { ef: eff.ef(0), filter },
            0,
            &mut scratch.visited,
            trace.as_deref_mut(),
        );
        scratch.q_pca = q_pca;
        scratch.store = store_scratch;
        scratch.mid_store = mid_scratch;
        scratch.dists = dists;
        self.put_scratch(scratch);
        // Leave internal-id space at the last possible moment: distances
        // were computed on the same rows either way, so a reordered
        // searcher's results differ from corpus order only in labels.
        let mut out: Vec<Neighbor> = found
            .into_iter()
            .map(|(dist, id)| Neighbor {
                id: self.perm.as_ref().map_or(id, |p| p.ext(id)),
                dist,
            })
            .collect();
        if let Some(k) = req.topk {
            out.truncate(k);
        }
        out
    }

    /// Full multi-layer pHNSW search with default knobs, optionally
    /// tracing.
    pub fn search_traced(&self, q: &[f32], trace: Option<&mut SearchTrace>) -> Vec<Neighbor> {
        self.search_request_traced(&SearchRequest::new(q), trace)
    }

    /// Search one request and return the trace (consumed by the hw
    /// simulator).
    pub fn search_request_full_trace(&self, req: &SearchRequest<'_>) -> (Vec<Neighbor>, SearchTrace) {
        let mut t = SearchTrace::new();
        let r = self.search_request_traced(req, Some(&mut t));
        (r, t)
    }

    /// Search and return the trace (default knobs).
    pub fn search_full_trace(&self, q: &[f32]) -> (Vec<Neighbor>, SearchTrace) {
        self.search_request_full_trace(&SearchRequest::new(q))
    }

    /// Data-parallel batch with an explicit worker ceiling — used by the
    /// segmented engine to split the core budget across concurrently
    /// fanning shards. Results are bitwise identical to
    /// [`AnnEngine::search_batch_req`] (chunking never affects per-query
    /// determinism).
    pub(crate) fn search_batch_req_capped(
        &self,
        reqs: &[SearchRequest],
        max_workers: usize,
    ) -> Vec<Vec<Neighbor>> {
        super::parallel_search_batch_req_capped(self, reqs, max_workers)
    }
}

impl AnnEngine for PhnswSearcher {
    fn name(&self) -> &str {
        "phnsw"
    }

    fn search_req(&self, req: &SearchRequest) -> Vec<Neighbor> {
        self.search_request_traced(req, None)
    }

    fn search_req_with_stats(&self, req: &SearchRequest) -> (Vec<Neighbor>, SearchStats) {
        let (r, t) = self.search_request_full_trace(req);
        (r, t.stats())
    }

    fn search_batch_req(&self, reqs: &[SearchRequest]) -> Vec<Vec<Neighbor>> {
        super::parallel_search_batch_req(self, reqs)
    }

    fn search_batch_req_with_stats(
        &self,
        reqs: &[SearchRequest],
    ) -> (Vec<Vec<Neighbor>>, SearchStats) {
        super::parallel_search_batch_req_with_stats(self, reqs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic::{generate, SyntheticConfig};
    use crate::dataset::ground_truth;
    use crate::graph::build::{build, BuildConfig};
    use crate::metrics::recall_at_k;
    use crate::search::config::SearchParams;
    use crate::search::hnsw::HnswSearcher;
    use crate::store::F32Store;

    struct Fixture {
        base: Arc<VectorSet>,
        queries: VectorSet,
        graph: Arc<HnswGraph>,
        gt: Vec<Vec<u32>>,
    }

    fn fixture(n: usize) -> Fixture {
        let cfg = SyntheticConfig { n_base: n, n_queries: 60, ..SyntheticConfig::tiny() };
        let (base, queries) = generate(&cfg);
        let graph = Arc::new(build(
            &base,
            &BuildConfig { m: 8, ef_construction: 100, ..Default::default() },
        ));
        let gt = ground_truth(&base, &queries, 10);
        Fixture { base: Arc::new(base), queries, graph, gt }
    }

    fn searcher(f: &Fixture, params: PhnswParams) -> PhnswSearcher {
        PhnswSearcher::build_from(f.graph.clone(), f.base.clone(), 8, params, 7)
    }

    /// Same stack but with the f32 filter codec (comparison path).
    fn searcher_f32(f: &Fixture, params: PhnswParams) -> PhnswSearcher {
        let pca = Arc::new(PcaModel::fit(&f.base, 8, 7));
        let low = Arc::new(F32Store::from_set(&pca.project_set(&f.base)));
        PhnswSearcher::with_store(f.graph.clone(), f.base.clone(), low, pca, params)
    }

    #[test]
    fn returns_sorted_unique_results() {
        let f = fixture(1500);
        let s = searcher(&f, PhnswParams { search: SearchParams { ef_upper: 1, ef_l0: 10 }, ..Default::default() });
        for q in f.queries.iter().take(10) {
            let res = s.search(q);
            assert!(!res.is_empty());
            for w in res.windows(2) {
                assert!(w[0].dist <= w[1].dist);
            }
            let ids: std::collections::HashSet<_> = res.iter().map(|n| n.id).collect();
            assert_eq!(ids.len(), res.len());
        }
    }

    #[test]
    fn default_codec_is_sq8() {
        let f = fixture(500);
        let s = searcher(&f, PhnswParams::default());
        assert_eq!(s.low_store().codec(), crate::store::Codec::Sq8);
        assert_eq!(s.low_store().row_bytes(), 8, "1 byte per PCA component");
    }

    #[test]
    fn recall_close_to_hnsw_with_generous_k() {
        // With a large filter size pHNSW degenerates toward plain HNSW, so
        // recall should be close.
        let f = fixture(2000);
        let sp = SearchParams { ef_upper: 1, ef_l0: 32 };
        let hnsw = HnswSearcher::new(f.graph.clone(), f.base.clone(), sp.clone());
        let phnsw = searcher(
            &f,
            PhnswParams { search: sp, k_schedule: vec![16, 16, 16] },
        );
        let collect = |e: &dyn AnnEngine| -> Vec<Vec<u32>> {
            f.queries
                .iter()
                .map(|q| e.search(q).into_iter().map(|n| n.id).take(10).collect())
                .collect()
        };
        let r_h = recall_at_k(&collect(&hnsw), &f.gt, 10);
        let r_p = recall_at_k(&collect(&phnsw), &f.gt, 10);
        assert!(r_h > 0.85, "hnsw recall {r_h}");
        assert!(r_p > r_h - 0.12, "phnsw recall {r_p} far below hnsw {r_h}");
    }

    #[test]
    fn sq8_filter_tracks_f32_filter() {
        // The quantized filter may reorder near-ties but must not change
        // recall materially — the f32 rerank guards the result list.
        let f = fixture(2000);
        let params = PhnswParams::default();
        let sq8 = searcher(&f, params.clone());
        let f32s = searcher_f32(&f, params);
        let collect = |e: &dyn AnnEngine| -> Vec<Vec<u32>> {
            f.queries
                .iter()
                .map(|q| e.search(q).into_iter().map(|n| n.id).take(10).collect())
                .collect()
        };
        let r_sq8 = recall_at_k(&collect(&sq8), &f.gt, 10);
        let r_f32 = recall_at_k(&collect(&f32s), &f.gt, 10);
        assert!(
            (r_sq8 - r_f32).abs() <= 0.01,
            "sq8 recall {r_sq8} drifted from f32 recall {r_f32}"
        );
    }

    #[test]
    fn smaller_k_means_fewer_highdim_dists() {
        let f = fixture(2000);
        let sp = SearchParams { ef_upper: 1, ef_l0: 10 };
        let s_small = searcher(&f, PhnswParams { search: sp.clone(), k_schedule: vec![4, 3, 3] });
        let s_big = searcher(&f, PhnswParams { search: sp, k_schedule: vec![24, 8, 3] });
        let mut tot_small = 0u64;
        let mut tot_big = 0u64;
        for q in f.queries.iter().take(20) {
            tot_small += s_small.search_with_stats(q).1.highdim_dists;
            tot_big += s_big.search_with_stats(q).1.highdim_dists;
        }
        assert!(
            tot_small < tot_big,
            "k=4 should compute fewer high-dim distances ({tot_small} vs {tot_big})"
        );
    }

    #[test]
    fn highdim_dists_bounded_by_k_per_hop() {
        let f = fixture(1000);
        let params = PhnswParams::default();
        let s = searcher(&f, params.clone());
        let (_, t) = s.search_full_trace(f.queries.row(0));
        for h in &t.hops {
            let k = params.k(h.layer as usize);
            assert!(
                h.n_highdim_dists as usize <= k,
                "hop on layer {} computed {} high-dim dists > k={k}",
                h.layer,
                h.n_highdim_dists
            );
            assert_eq!(h.n_lowdim_dists, h.n_neighbors);
            assert_eq!(h.n_ksort, 1);
        }
    }

    #[test]
    fn filter_reduces_highdim_traffic_vs_hnsw() {
        // The headline claim: pHNSW's high-dim distance count (and thus
        // irregular high-dim fetch traffic) is far below plain HNSW's.
        let f = fixture(2000);
        let sp = SearchParams { ef_upper: 1, ef_l0: 10 };
        let hnsw = HnswSearcher::new(f.graph.clone(), f.base.clone(), sp.clone());
        let phnsw = searcher(&f, PhnswParams { search: sp, ..Default::default() });
        let mut h_tot = 0u64;
        let mut p_tot = 0u64;
        for q in f.queries.iter().take(20) {
            h_tot += hnsw.search_with_stats(q).1.highdim_dists;
            p_tot += phnsw.search_with_stats(q).1.highdim_dists;
        }
        assert!(
            (p_tot as f64) < 0.8 * h_tot as f64,
            "expected sizable high-dim reduction: phnsw {p_tot} vs hnsw {h_tot}"
        );
    }

    #[test]
    fn exact_base_vector_query_finds_itself() {
        let f = fixture(1000);
        let s = searcher(&f, PhnswParams::default());
        for id in [5u32, 500] {
            let res = s.search(f.base.row(id as usize));
            assert_eq!(res[0].id, id);
            assert_eq!(res[0].dist, 0.0);
        }
    }

    #[test]
    fn deterministic_across_calls() {
        let f = fixture(800);
        let s = searcher(&f, PhnswParams::default());
        let first = s.search(f.queries.row(3));
        for _ in 0..3 {
            assert_eq!(s.search(f.queries.row(3)), first);
        }
    }

    #[test]
    fn search_batch_matches_sequential_bitwise() {
        let f = fixture(1200);
        let s = searcher(&f, PhnswParams::default());
        let qrefs: Vec<&[f32]> = (0..40).map(|i| f.queries.row(i)).collect();
        let sequential: Vec<Vec<Neighbor>> = qrefs.iter().map(|q| s.search(q)).collect();
        for _ in 0..2 {
            assert_eq!(
                s.search_batch(&qrefs),
                sequential,
                "scratch-pooled data-parallel batch must be bitwise identical"
            );
        }
    }

    #[test]
    fn nan_query_does_not_panic() {
        let f = fixture(600);
        let s = searcher(&f, PhnswParams::default());
        let mut q = f.base.row(0).to_vec();
        q[0] = f32::NAN;
        let _ = s.search(&q);
        // The scratch pool must stay healthy afterwards.
        let ok = s.search(f.base.row(7));
        assert_eq!(ok[0].id, 7);
    }

    #[test]
    #[should_panic(expected = "not the PCA projection")]
    fn constructor_rejects_mismatched_low_table() {
        let f = fixture(300);
        let pca = Arc::new(PcaModel::fit(&f.base, 8, 7));
        let mut wrong = pca.project_set(&f.base);
        // corrupt one row badly
        for x in wrong.row_mut(150) {
            *x += 1000.0;
        }
        let _ = PhnswSearcher::new(
            f.graph.clone(),
            f.base.clone(),
            Arc::new(wrong),
            pca,
            PhnswParams::default(),
        );
    }
}
