//! Locality-preserving node relabeling — the hub-first reordering pass.
//!
//! HNSW traversal concentrates on upper-level hub nodes and the
//! entry-point neighborhood (Malkov & Yashunin, arXiv 1603.09320), but
//! node ids are corpus-order, so every beam hop gathers adjacency rows
//! and LOWQ/MIDQ/HIGH rows from effectively random offsets. This module
//! computes a [`Permutation`] that places nodes hub-first — descending
//! max level, then BFS order over layer 0 seeded at the entry point —
//! and applies it *physically*: the CSR arrays, the quantized filter
//! tables, and the f32 rerank rows are all rewritten so graph-adjacent
//! nodes are byte-adjacent. The hot working set of a search then lives
//! on a handful of contiguous cache lines (owned mode) or pages
//! (`--mmap` mode), instead of being sprayed across the table.
//!
//! Reordering changes *labels only*: the graph stays isomorphic and
//! every distance is computed over the same bytes, so a search on a
//! reordered index returns identical results once ids are translated
//! back at the engine boundary. The mapping is:
//!
//! * `ext_of[internal] = external` — row `internal` of every reordered
//!   table holds the vector originally labeled `external`.
//! * `int_of[external] = internal` — the inverse, used to translate
//!   incoming `IdFilter`s and ground-truth row probes.
//!
//! `ext_of` is what the v3 bundle persists (the `PERM` section); the
//! inverse is recomputed at load.

use super::HnswGraph;
use crate::dataset::VectorSet;
use anyhow::{ensure, Result};

/// How a build (or live seal/compact) relabels nodes before freezing
/// the shard's tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReorderMode {
    /// Keep corpus order (the identity labeling; no `PERM` section).
    #[default]
    None,
    /// Hub-first: descending node level, BFS over layer 0 from the
    /// entry point as the within-level order.
    HubBfs,
}

impl ReorderMode {
    /// Parse a CLI value (`none` | `hub-bfs`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "none" | "identity" => Ok(Self::None),
            "hub-bfs" | "hub_bfs" | "hubbfs" => Ok(Self::HubBfs),
            other => anyhow::bail!("unknown reorder mode {other:?} (expected hub-bfs|none)"),
        }
    }

    /// Display label (the `--reorder` CLI spelling).
    pub fn label(&self) -> &'static str {
        match self {
            Self::None => "none",
            Self::HubBfs => "hub-bfs",
        }
    }
}

/// A bijective relabeling of the `n` nodes of one shard, stored in both
/// directions so either translation is O(1).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Permutation {
    /// `ext_of[internal] = external` (the persisted direction).
    ext_of: Vec<u32>,
    /// `int_of[external] = internal` (derived inverse).
    int_of: Vec<u32>,
}

impl Permutation {
    /// Build from the persisted `ext_of` direction, validating that it
    /// is a bijection over `0..n`.
    pub fn from_ext_of(ext_of: Vec<u32>) -> Result<Self> {
        let n = ext_of.len();
        let mut int_of = vec![u32::MAX; n];
        for (internal, &external) in ext_of.iter().enumerate() {
            ensure!(
                (external as usize) < n,
                "permutation entry {external} out of range for {n} nodes"
            );
            ensure!(
                int_of[external as usize] == u32::MAX,
                "permutation maps external id {external} twice"
            );
            int_of[external as usize] = internal as u32;
        }
        Ok(Self { ext_of, int_of })
    }

    /// The identity relabeling over `n` nodes.
    pub fn identity(n: usize) -> Self {
        let ext_of: Vec<u32> = (0..n as u32).collect();
        Self { int_of: ext_of.clone(), ext_of }
    }

    /// Hub-first order for `graph`: nodes sorted by descending max
    /// level, breaking ties by BFS rank over layer 0 seeded at the
    /// entry point (nodes unreachable on layer 0 keep corpus order at
    /// the tail of their level class). The BFS leg follows neighbor
    /// lists in stored order, so the relabeling is deterministic.
    pub fn hub_bfs(graph: &HnswGraph) -> Self {
        let n = graph.len();
        if n == 0 {
            return Self::identity(0);
        }
        // BFS rank over layer 0 from the entry point.
        let mut rank = vec![u32::MAX; n];
        let mut queue = std::collections::VecDeque::new();
        let mut next = 0u32;
        let mut push = |node: u32, rank: &mut Vec<u32>, queue: &mut std::collections::VecDeque<u32>| {
            if rank[node as usize] == u32::MAX {
                rank[node as usize] = next;
                next += 1;
                queue.push_back(node);
            }
        };
        push(graph.entry_point(), &mut rank, &mut queue);
        while let Some(node) = queue.pop_front() {
            for &nb in graph.neighbors(node, 0) {
                push(nb, &mut rank, &mut queue);
            }
        }
        // Unreached nodes (disconnected layer 0) go after every reached
        // one, in corpus order.
        for (node, r) in rank.iter_mut().enumerate() {
            if *r == u32::MAX {
                *r = next + node as u32;
            }
        }
        let mut ext_of: Vec<u32> = (0..n as u32).collect();
        ext_of.sort_by_key(|&node| {
            (std::cmp::Reverse(graph.level(node)), rank[node as usize])
        });
        Self::from_ext_of(ext_of).expect("hub-bfs order is a bijection by construction")
    }

    /// Node count this permutation covers.
    pub fn len(&self) -> usize {
        self.ext_of.len()
    }

    /// True for the zero-node permutation.
    pub fn is_empty(&self) -> bool {
        self.ext_of.is_empty()
    }

    /// True when the relabeling is the identity (nothing moved).
    pub fn is_identity(&self) -> bool {
        self.ext_of.iter().enumerate().all(|(i, &e)| e == i as u32)
    }

    /// External id of reordered row `internal`.
    #[inline]
    pub fn ext(&self, internal: u32) -> u32 {
        self.ext_of[internal as usize]
    }

    /// Reordered row holding external id `external`.
    #[inline]
    pub fn int(&self, external: u32) -> u32 {
        self.int_of[external as usize]
    }

    /// The persisted direction (`ext_of[internal] = external`).
    pub fn ext_of(&self) -> &[u32] {
        &self.ext_of
    }

    /// The inverse permutation (swap the two directions).
    pub fn inverse(&self) -> Self {
        Self { ext_of: self.int_of.clone(), int_of: self.ext_of.clone() }
    }

    /// Relabel a frozen (or staging) graph: row order, neighbor ids,
    /// and the entry point all move together, with each node's neighbor
    /// list order preserved — the reordered graph is isomorphic to the
    /// input and search walks it in the same sequence.
    pub fn apply_to_graph(&self, graph: &HnswGraph) -> Result<HnswGraph> {
        let n = graph.len();
        ensure!(n == self.len(), "permutation covers {} nodes, graph has {n}", self.len());
        if n == 0 {
            let mut g = HnswGraph::empty(graph.m(), graph.m0());
            g.freeze();
            return Ok(g);
        }
        let mut levels = Vec::with_capacity(n);
        for internal in 0..n as u32 {
            levels.push(graph.level(self.ext(internal)) as u8);
        }
        let n_levels = graph.max_level() + 1;
        let mut parts: Vec<(Vec<u32>, Vec<u32>)> = Vec::with_capacity(n_levels);
        for l in 0..n_levels {
            let mut offsets = Vec::with_capacity(n + 1);
            offsets.push(0u32);
            let mut neighbors = Vec::with_capacity(graph.edges_at_level(l));
            for internal in 0..n as u32 {
                for &nb in graph.neighbors(self.ext(internal), l) {
                    neighbors.push(self.int(nb));
                }
                offsets.push(neighbors.len() as u32);
            }
            parts.push((offsets, neighbors));
        }
        HnswGraph::from_csr_parts(
            graph.m(),
            graph.m0(),
            self.int(graph.entry_point()),
            graph.max_level(),
            levels,
            parts,
        )
    }

    /// Relabel a vector set: reordered row `internal` holds the vector
    /// originally at row `ext_of[internal]`.
    pub fn apply_to_set(&self, set: &VectorSet) -> VectorSet {
        assert_eq!(set.len(), self.len(), "permutation/set length mismatch");
        let mut out = VectorSet::new(set.dim());
        for internal in 0..self.len() as u32 {
            out.push(set.row(self.ext(internal) as usize));
        }
        out
    }

    /// Relabel a plain per-node array (e.g. a `.ids` sidecar map).
    pub fn apply_to_ids(&self, ids: &[u32]) -> Vec<u32> {
        assert_eq!(ids.len(), self.len(), "permutation/ids length mismatch");
        (0..self.len() as u32).map(|internal| ids[self.ext(internal) as usize]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic::{generate, SyntheticConfig};
    use crate::graph::build::{build, BuildConfig};
    use crate::proptest_lite;
    use crate::rng::Pcg32;

    fn random_graph(n: usize, seed: u64) -> HnswGraph {
        let cfg = SyntheticConfig { n_base: n, n_queries: 1, seed, ..SyntheticConfig::tiny() };
        let (base, _) = generate(&cfg);
        build(&base, &BuildConfig { m: 6, ef_construction: 32, ..Default::default() })
    }

    #[test]
    fn mode_parses_and_labels() {
        assert_eq!(ReorderMode::parse("hub-bfs").unwrap(), ReorderMode::HubBfs);
        assert_eq!(ReorderMode::parse("none").unwrap(), ReorderMode::None);
        assert!(ReorderMode::parse("zorder").is_err());
        assert_eq!(ReorderMode::HubBfs.label(), "hub-bfs");
        assert_eq!(ReorderMode::default(), ReorderMode::None);
    }

    #[test]
    fn from_ext_of_rejects_non_bijections() {
        assert!(Permutation::from_ext_of(vec![0, 0]).is_err(), "duplicate entry");
        assert!(Permutation::from_ext_of(vec![0, 2]).is_err(), "out of range");
        assert!(Permutation::from_ext_of(vec![1, 0]).is_ok());
    }

    #[test]
    fn identity_roundtrips() {
        let p = Permutation::identity(5);
        assert!(p.is_identity());
        assert_eq!(p.ext(3), 3);
        assert_eq!(p.int(3), 3);
        assert_eq!(p.inverse(), p);
    }

    /// Proptest-style: random permutations compose with their inverse to
    /// the identity in both directions.
    #[test]
    fn prop_perm_compose_inverse_is_identity() {
        proptest_lite::run(
            &proptest_lite::Config { cases: 64, ..Default::default() },
            |rng: &mut Pcg32| {
                let n = rng.range(1, 200);
                let mut ext_of: Vec<u32> = (0..n as u32).collect();
                // Fisher–Yates with the harness RNG.
                for i in (1..n).rev() {
                    let j = rng.below(i as u32 + 1) as usize;
                    ext_of.swap(i, j);
                }
                ext_of
            },
            |ext_of| {
                let p = Permutation::from_ext_of(ext_of.clone()).unwrap();
                let inv = p.inverse();
                (0..ext_of.len() as u32).all(|i| {
                    p.int(p.ext(i)) == i
                        && p.ext(p.int(i)) == i
                        && inv.ext(i) == p.int(i)
                        && inv.int(i) == p.ext(i)
                })
            },
        );
    }

    /// Proptest-style: applying a random permutation to a graph
    /// preserves per-node degree and neighbor-list order, and applying
    /// the inverse to the result restores the original graph exactly.
    #[test]
    fn prop_graph_apply_preserves_structure_and_inverts() {
        let g = random_graph(300, 9);
        proptest_lite::run(
            &proptest_lite::Config { cases: 16, ..Default::default() },
            |rng: &mut Pcg32| {
                let n = g.len();
                let mut ext_of: Vec<u32> = (0..n as u32).collect();
                for i in (1..n).rev() {
                    let j = rng.below(i as u32 + 1) as usize;
                    ext_of.swap(i, j);
                }
                ext_of
            },
            |ext_of| {
                let p = Permutation::from_ext_of(ext_of.clone()).unwrap();
                let pg = p.apply_to_graph(&g).unwrap();
                // Degree and list order are preserved under relabeling.
                for internal in 0..pg.len() as u32 {
                    let ext = p.ext(internal);
                    if pg.level(internal) != g.level(ext) {
                        return false;
                    }
                    for l in 0..=g.level(ext) {
                        let want: Vec<u32> =
                            g.neighbors(ext, l).iter().map(|&nb| p.int(nb)).collect();
                        if pg.neighbors(internal, l) != want.as_slice() {
                            return false;
                        }
                    }
                }
                if pg.entry_point() != p.int(g.entry_point()) {
                    return false;
                }
                // perm ∘ inverse = identity on the graph itself.
                let back = p.inverse().apply_to_graph(&pg).unwrap();
                for node in 0..g.len() as u32 {
                    for l in 0..=g.level(node) {
                        if back.neighbors(node, l) != g.neighbors(node, l) {
                            return false;
                        }
                    }
                }
                back.entry_point() == g.entry_point() && back.check_invariants().is_empty()
            },
        );
    }

    #[test]
    fn hub_bfs_orders_hubs_first() {
        let g = random_graph(400, 3);
        let p = Permutation::hub_bfs(&g);
        assert_eq!(p.len(), g.len());
        // Levels are non-increasing along the new internal order.
        for w in 0..p.len() as u32 - 1 {
            assert!(
                g.level(p.ext(w)) >= g.level(p.ext(w + 1)),
                "internal {w}: level order violated"
            );
        }
        // The entry point becomes internal id 0.
        assert_eq!(p.ext(0), g.entry_point());
        let pg = p.apply_to_graph(&g).unwrap();
        assert_eq!(pg.entry_point(), 0);
        assert!(pg.check_invariants().is_empty());
        assert_eq!(pg.nodes_at_level(0), g.nodes_at_level(0));
        assert_eq!(pg.edges_at_level(0), g.edges_at_level(0));
    }

    #[test]
    fn apply_to_set_and_ids_move_rows_together() {
        let mut set = VectorSet::new(2);
        for i in 0..4 {
            set.push(&[i as f32, -(i as f32)]);
        }
        let p = Permutation::from_ext_of(vec![2, 0, 3, 1]).unwrap();
        let out = p.apply_to_set(&set);
        assert_eq!(out.row(0), &[2.0, -2.0]);
        assert_eq!(out.row(3), &[1.0, -1.0]);
        assert_eq!(p.apply_to_ids(&[10, 11, 12, 13]), vec![12, 10, 13, 11]);
    }

    #[test]
    fn empty_and_single_node_graphs_reorder() {
        let p = Permutation::hub_bfs(&{
            let mut g = HnswGraph::empty(4, 8);
            g.freeze();
            g
        });
        assert!(p.is_empty());
        let mut g = HnswGraph::empty(4, 8);
        g.add_node(0);
        g.freeze();
        let p = Permutation::hub_bfs(&g);
        assert!(p.is_identity());
        let pg = p.apply_to_graph(&g).unwrap();
        assert_eq!(pg.len(), 1);
    }
}
