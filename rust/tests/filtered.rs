//! Integration tests for request-scoped search: the filtered-ANN
//! contract (results ⊆ allowed ids, recall floors over the allowed
//! subset), per-request topk/ef semantics, the unfiltered-default
//! bitwise regression pin at every layer, and a coordinator round-trip
//! carrying a filter end to end.

use phnsw::coordinator::{Query, Server, ServerConfig};
use phnsw::dataset::synthetic::{generate, SyntheticConfig};
use phnsw::dataset::{ground_truth_filtered, VectorSet};
use phnsw::graph::build::{build, BuildConfig};
use phnsw::metrics::recall_at_k;
use phnsw::search::{
    AnnEngine, HnswSearcher, IdFilter, PhnswParams, PhnswSearcher, SearchParams, SearchRequest,
};
use phnsw::segment::{build_segmented, SegmentSpec, ShardAssignment};
use std::sync::Arc;

const DIM_LOW: usize = 8;
const PCA_SEED: u64 = 7;

struct Fixture {
    base: Arc<VectorSet>,
    queries: VectorSet,
    bc: BuildConfig,
}

fn fixture(n: usize, nq: usize) -> Fixture {
    let cfg = SyntheticConfig { n_base: n, n_queries: nq, ..SyntheticConfig::tiny() };
    let (base, queries) = generate(&cfg);
    let bc = BuildConfig { m: 8, ef_construction: 100, ..Default::default() };
    Fixture { base: Arc::new(base), queries, bc }
}

fn phnsw(f: &Fixture) -> PhnswSearcher {
    let graph = Arc::new(build(&f.base, &f.bc));
    PhnswSearcher::build_from(graph, f.base.clone(), DIM_LOW, PhnswParams::default(), PCA_SEED)
}

fn hnsw(f: &Fixture) -> HnswSearcher {
    let graph = Arc::new(build(&f.base, &f.bc));
    HnswSearcher::new(graph, f.base.clone(), SearchParams::default())
}

/// Recall@10 of `engine` under `filter`, against exact ground truth
/// restricted to the allowed subset.
fn filtered_recall(
    engine: &dyn AnnEngine,
    f: &Fixture,
    filter: &Arc<IdFilter>,
) -> f64 {
    let gt = ground_truth_filtered(&f.base, &f.queries, 10, |id| filter.allows(id));
    let results: Vec<Vec<u32>> = f
        .queries
        .iter()
        .map(|q| {
            let req = SearchRequest::new(q).with_topk(10).with_filter(filter.clone());
            let res = engine.search_req(&req);
            assert!(
                res.iter().all(|n| filter.allows(n.id)),
                "engine {} leaked a disallowed id",
                engine.name()
            );
            res.into_iter().map(|n| n.id).collect()
        })
        .collect();
    recall_at_k(&results, &gt, 10)
}

#[test]
fn results_only_ever_contain_allowed_ids() {
    // Property sweep: random filters across selectivities and seeds, all
    // three engine shapes; every returned id must be allowed.
    let f = fixture(1500, 12);
    let mono = phnsw(&f);
    let plain = hnsw(&f);
    let idx = build_segmented(
        &f.base,
        &f.bc,
        DIM_LOW,
        PCA_SEED,
        &SegmentSpec {
            n_shards: 3,
            build_threads: 2,
            assignment: ShardAssignment::RoundRobin,
            ..Default::default()
        },
    );
    let seg = idx.engine(PhnswParams::default());
    let engines: [&dyn AnnEngine; 3] = [&mono, &plain, &seg];
    for (i, &sel) in [0.5, 0.1, 0.02].iter().enumerate() {
        let filter = Arc::new(IdFilter::random(f.base.len(), sel, 100 + i as u64));
        for engine in engines {
            for q in f.queries.iter() {
                let res = engine
                    .search_req(&SearchRequest::new(q).with_topk(10).with_filter(filter.clone()));
                assert!(
                    res.iter().all(|n| filter.allows(n.id)),
                    "{} returned a disallowed id at selectivity {sel}",
                    engine.name()
                );
                let ids: std::collections::HashSet<_> = res.iter().map(|n| n.id).collect();
                assert_eq!(ids.len(), res.len(), "duplicate ids from {}", engine.name());
            }
        }
    }
}

#[test]
fn unfiltered_default_request_is_bitwise_identical_to_search() {
    // The tentpole's regression pin at the searcher layer: a request
    // with default knobs — and explicit knobs that *equal* the defaults —
    // must reproduce the knob-free path bit for bit.
    let f = fixture(1500, 25);
    let s = phnsw(&f);
    let h = hnsw(&f);
    for q in f.queries.iter() {
        let legacy = s.search(q);
        assert_eq!(s.search_req(&SearchRequest::new(q)), legacy);
        assert_eq!(
            s.search_req(&SearchRequest::new(q).with_ef(SearchParams::default())),
            legacy,
            "an ef override equal to the engine default must be the identity"
        );
        assert_eq!(
            s.search_req(&SearchRequest::new(q).with_topk(SearchParams::default().ef_l0)),
            legacy,
            "topk == ef_l0 must be the identity"
        );
        let legacy_h = h.search(q);
        assert_eq!(h.search_req(&SearchRequest::new(q)), legacy_h);
        // topk below ef_l0 is plain truncation of the same list.
        assert_eq!(
            s.search_req(&SearchRequest::new(q).with_topk(3)),
            legacy[..3.min(legacy.len())].to_vec()
        );
    }
}

#[test]
fn unfiltered_default_request_is_bitwise_identical_for_segmented_and_batch() {
    let f = fixture(1200, 20);
    let idx = build_segmented(
        &f.base,
        &f.bc,
        DIM_LOW,
        PCA_SEED,
        &SegmentSpec {
            n_shards: 4,
            build_threads: 2,
            assignment: ShardAssignment::RoundRobin,
            ..Default::default()
        },
    );
    let seg = idx.engine(PhnswParams::default());
    let reqs: Vec<SearchRequest> = f.queries.iter().map(SearchRequest::new).collect();
    let legacy: Vec<_> = f.queries.iter().map(|q| seg.search(q)).collect();
    for (req, want) in reqs.iter().zip(&legacy) {
        assert_eq!(&seg.search_req(req), want);
    }
    assert_eq!(seg.search_batch_req(&reqs), legacy, "batch request path matches too");
}

#[test]
fn filtered_recall_floors_monolithic() {
    let f = fixture(3000, 50);
    let s = phnsw(&f);
    for (sel, seed) in [(0.5, 21u64), (0.1, 22u64)] {
        let filter = Arc::new(IdFilter::random(f.base.len(), sel, seed));
        let r = filtered_recall(&s, &f, &filter);
        assert!(
            r >= 0.85,
            "monolithic filtered recall@10 = {r:.3} below floor at selectivity {sel}"
        );
    }
}

#[test]
fn filtered_recall_floor_segmented() {
    let f = fixture(3000, 50);
    let idx = build_segmented(
        &f.base,
        &f.bc,
        DIM_LOW,
        PCA_SEED,
        &SegmentSpec {
            n_shards: 4,
            build_threads: 4,
            assignment: ShardAssignment::RoundRobin,
            ..Default::default()
        },
    );
    let seg = idx.engine(PhnswParams::default());
    let filter = Arc::new(IdFilter::random(f.base.len(), 0.1, 22));
    let r = filtered_recall(&seg, &f, &filter);
    assert!(r >= 0.85, "segmented filtered recall@10 = {r:.3} below floor at selectivity 0.1");
}

#[test]
fn segmented_filtered_parity_s1_vs_s4() {
    let f = fixture(2000, 40);
    let mk = |shards: usize| {
        build_segmented(
            &f.base,
            &f.bc,
            DIM_LOW,
            PCA_SEED,
            &SegmentSpec {
                n_shards: shards,
                build_threads: 2,
                assignment: ShardAssignment::RoundRobin,
                ..Default::default()
            },
        )
    };
    let s1 = mk(1).engine(PhnswParams::default());
    let s4 = mk(4).engine(PhnswParams::default());
    let mono = phnsw(&f);
    let filter = Arc::new(IdFilter::random(f.base.len(), 0.2, 33));

    // S=1 is bitwise the monolithic searcher, filtered requests included.
    for q in f.queries.iter() {
        let req = SearchRequest::new(q).with_topk(10).with_filter(filter.clone());
        assert_eq!(
            s1.search_req(&req),
            mono.search_req(&req),
            "S=1 filtered search must be bitwise identical to the monolithic searcher"
        );
    }
    // S=4 sees the same allowed subset through shard-local filters and
    // must hold recall parity with S=1 (merge + per-shard boost differ
    // only in schedule, not in quality).
    let r1 = filtered_recall(&s1, &f, &filter);
    let r4 = filtered_recall(&s4, &f, &filter);
    assert!(r1 > 0.85, "S=1 filtered recall {r1:.3} suspiciously low");
    assert!(
        r4 >= r1 - 0.02,
        "S=4 filtered recall {r4:.3} more than 0.02 below S=1 {r1:.3}"
    );
}

#[test]
fn empty_and_tiny_filters_degrade_gracefully() {
    let f = fixture(800, 5);
    let s = phnsw(&f);
    let none = Arc::new(IdFilter::from_ids(f.base.len(), std::iter::empty()));
    let one = Arc::new(IdFilter::from_ids(f.base.len(), [17u32]));
    // A subset smaller than the beam takes the exact brute-force
    // fallback, so tiny tenants get exact answers, not a graph walk.
    let few = Arc::new(IdFilter::from_ids(f.base.len(), [3u32, 90, 200, 555]));
    for q in f.queries.iter() {
        assert!(s.search_req(&SearchRequest::new(q).with_filter(none.clone())).is_empty());
        let res = s.search_req(&SearchRequest::new(q).with_topk(10).with_filter(one.clone()));
        assert_eq!(res.len(), 1, "singleton filter answers exactly");
        assert_eq!(res[0].id, 17);
        let res = s.search_req(&SearchRequest::new(q).with_topk(2).with_filter(few.clone()));
        let gt = phnsw::dataset::exact_topk_filtered(&f.base, q, 2, |id| few.allows(id));
        assert_eq!(res.iter().map(|n| n.id).collect::<Vec<_>>(), gt, "tiny filters are exact");
    }
}

#[test]
fn coordinator_round_trip_carries_filter_end_to_end() {
    let f = fixture(1500, 20);
    let engine: Arc<dyn AnnEngine> = Arc::new(phnsw(&f));
    let direct = phnsw(&f);
    let server = Server::builder()
        .config(ServerConfig { workers: 2, ..Default::default() })
        .engine("phnsw", engine)
        .start()
        .unwrap();
    let h = server.handle();
    let filter = Arc::new(IdFilter::random(f.base.len(), 0.25, 44));
    for qi in 0..f.queries.len() {
        let q = Query::new(f.queries.row(qi).to_vec())
            .with_topk(5)
            .with_ef(SearchParams { ef_l0: 16, ..SearchParams::default() })
            .with_filter(filter.clone());
        let res = h.query_blocking(q).unwrap();
        assert!(res.neighbors.len() <= 5);
        assert!(
            res.neighbors.iter().all(|n| filter.allows(n.id)),
            "served filtered query leaked a disallowed id"
        );
        // The served result equals a direct engine call with the same
        // request — the batch dispatch changes nothing.
        let want = direct.search_req(
            &SearchRequest::new(f.queries.row(qi))
                .with_topk(5)
                .with_ef(SearchParams { ef_l0: 16, ..SearchParams::default() })
                .with_filter(filter.clone()),
        );
        assert_eq!(res.neighbors, want, "query {qi} diverged through the coordinator");
        assert!(res.queue_wait + res.exec <= res.latency + std::time::Duration::from_millis(5));
    }
    server.shutdown();
}
