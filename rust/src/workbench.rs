//! Experiment workbench: one place that assembles the full stack
//! (synthetic corpus → PCA → HNSW graph → searchers → DB layouts →
//! processor simulation) so the CLI, the benches, and the examples all
//! drive identical pipelines.
//!
//! Graphs and ground truth are cached on disk keyed by their parameters —
//! a bench re-run pays seconds, not the full index build.

use crate::dataset::synthetic::{generate, SyntheticConfig};
use crate::dataset::{ground_truth, VectorSet};
use crate::db::{DbLayout, LayoutKind};
use crate::dram::{DramConfig, DramSim};
use crate::energy::EnergyConfig;
use crate::graph::build::{build, BuildConfig};
use crate::graph::{serialize, HnswGraph};
use crate::hw::{simulate_workload, CoreConfig, EngineKind, WorkloadSim};
use crate::metrics::{qps, recall_at_k};
use crate::pca::PcaModel;
use crate::runtime::IndexBundle;
use crate::search::{
    AnnEngine, HnswSearcher, PhnswParams, PhnswSearcher, SearchParams, SearchTrace,
};
use crate::store::{Codec, F32Store, Sq8Store, VectorStore};
use std::sync::Arc;
use std::time::Instant;

/// Workbench scale / parameters.
#[derive(Debug, Clone)]
pub struct WorkbenchConfig {
    /// Base corpus size.
    pub n_base: usize,
    /// Query count.
    pub n_queries: usize,
    /// HNSW M.
    pub m: usize,
    /// efConstruction.
    pub ef_construction: usize,
    /// PCA dimensionality.
    pub dim_low: usize,
    /// Dataset seed.
    pub seed: u64,
    /// Ground-truth depth.
    pub k_gt: usize,
}

impl Default for WorkbenchConfig {
    fn default() -> Self {
        Self {
            n_base: 100_000,
            n_queries: 500,
            m: crate::params::M,
            ef_construction: 128,
            dim_low: crate::params::DIM_LOW,
            seed: 0x5EED_0001,
            k_gt: 10,
        }
    }
}

impl WorkbenchConfig {
    /// Small scale for quick runs / CI.
    pub fn small() -> Self {
        Self { n_base: 10_000, n_queries: 200, ef_construction: 96, ..Self::default() }
    }

    /// Cache key for graph/gt reuse.
    fn cache_key(&self) -> String {
        format!(
            "n{}_q{}_m{}_efc{}_dl{}_s{:x}_k{}",
            self.n_base, self.n_queries, self.m, self.ef_construction, self.dim_low, self.seed, self.k_gt
        )
    }
}

/// Fully assembled benchmark stack.
pub struct Workbench {
    /// Configuration used.
    pub cfg: WorkbenchConfig,
    /// Base corpus (high-dim).
    pub base: Arc<VectorSet>,
    /// Query set.
    pub queries: VectorSet,
    /// Exact ground truth (top `k_gt`).
    pub gt: Vec<Vec<u32>>,
    /// Built HNSW graph.
    pub graph: Arc<HnswGraph>,
    /// Trained PCA.
    pub pca: Arc<PcaModel>,
    /// Projected corpus.
    pub base_low: Arc<VectorSet>,
}

fn cache_dir() -> std::path::PathBuf {
    std::env::var_os("PHNSW_CACHE")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("target/phnsw_cache"))
}

impl Workbench {
    /// Assemble (generate + build or load from cache) the full stack.
    pub fn assemble(cfg: WorkbenchConfig) -> crate::Result<Self> {
        let t0 = Instant::now();
        let syn = SyntheticConfig {
            n_base: cfg.n_base,
            n_queries: cfg.n_queries,
            seed: cfg.seed,
            ..SyntheticConfig::default()
        };
        let (base, queries) = generate(&syn);
        log::info!("dataset generated in {:.1?}", t0.elapsed());

        let dir = cache_dir();
        std::fs::create_dir_all(&dir).ok();
        let graph_path = dir.join(format!("{}.hnsw", cfg.cache_key()));
        let gt_path = dir.join(format!("{}.gt.ivecs", cfg.cache_key()));

        let graph = match serialize::load(&graph_path) {
            Ok(g) if g.len() == base.len() => {
                log::info!("graph loaded from cache {}", graph_path.display());
                g
            }
            _ => {
                let t = Instant::now();
                let g = build(
                    &base,
                    &BuildConfig {
                        m: cfg.m,
                        ef_construction: cfg.ef_construction,
                        ..Default::default()
                    },
                );
                log::info!("graph built in {:.1?}", t.elapsed());
                serialize::save(&g, &graph_path).ok();
                g
            }
        };

        let gt = match crate::dataset::io::read_ivecs(&gt_path) {
            Ok(g) if g.len() == queries.len() => g,
            _ => {
                let t = Instant::now();
                let g = ground_truth(&base, &queries, cfg.k_gt);
                log::info!("ground truth in {:.1?}", t.elapsed());
                crate::dataset::io::write_ivecs(&gt_path, &g).ok();
                g
            }
        };

        let pca = Arc::new(PcaModel::fit(&base, cfg.dim_low, cfg.seed));
        let base = Arc::new(base);
        let base_low = Arc::new(pca.project_set(&base));
        Ok(Self { cfg, base, queries, gt, graph: Arc::new(graph), pca, base_low })
    }

    /// Plain HNSW searcher (HNSW-CPU baseline).
    pub fn hnsw(&self, params: SearchParams) -> HnswSearcher {
        HnswSearcher::new(self.graph.clone(), self.base.clone(), params)
    }

    /// pHNSW searcher (pHNSW-CPU + the traced workload source for the
    /// sim). The filter table is SQ8-quantized — the system default.
    pub fn phnsw(&self, params: PhnswParams) -> PhnswSearcher {
        PhnswSearcher::new(
            self.graph.clone(),
            self.base.clone(),
            self.base_low.clone(),
            self.pca.clone(),
            params,
        )
    }

    /// pHNSW searcher with the f32 low-dim codec — the comparison path
    /// recall regression tests hold the SQ8 default against.
    pub fn phnsw_f32(&self, params: PhnswParams) -> PhnswSearcher {
        let low: Arc<dyn VectorStore> = Arc::new(F32Store::from_set(&self.base_low));
        PhnswSearcher::with_store(
            self.graph.clone(),
            self.base.clone(),
            low,
            self.pca.clone(),
            params,
        )
    }

    /// pHNSW searcher with the mid-stage cascade table fitted (SQ8 over
    /// the high-dim corpus) — `Staged`-tier requests engage the
    /// three-stage cascade; `Exact` requests stay bitwise identical to
    /// [`Workbench::phnsw`].
    pub fn phnsw_mid(&self, params: PhnswParams) -> PhnswSearcher {
        let low: Arc<dyn VectorStore> = Arc::new(Sq8Store::from_set(&self.base_low));
        let mid: Arc<dyn VectorStore> = Arc::new(Sq8Store::from_set(&self.base));
        PhnswSearcher::with_stores(
            self.graph.clone(),
            self.base.clone(),
            low,
            Some(mid),
            self.pca.clone(),
            params,
        )
    }

    /// Measure recall@k + wall-clock QPS of an engine over the query set.
    pub fn evaluate(&self, engine: &dyn AnnEngine, k: usize) -> EngineEval {
        let t0 = Instant::now();
        let results: Vec<Vec<u32>> = self
            .queries
            .iter()
            .map(|q| engine.search(q).into_iter().map(|n| n.id).take(k).collect())
            .collect();
        let elapsed = t0.elapsed();
        EngineEval {
            recall: recall_at_k(&results, &self.gt, k),
            qps: qps(self.queries.len(), elapsed),
            queries: self.queries.len(),
        }
    }

    /// Collect per-query traces from a pHNSW searcher (sim input).
    pub fn phnsw_traces(&self, params: PhnswParams, limit: usize) -> Vec<SearchTrace> {
        let s = self.phnsw(params);
        self.queries
            .iter()
            .take(limit)
            .map(|q| s.search_full_trace(q).1)
            .collect()
    }

    /// Collect per-query traces from the plain HNSW searcher.
    pub fn hnsw_traces(&self, params: SearchParams, limit: usize) -> Vec<SearchTrace> {
        let s = self.hnsw(params);
        self.queries
            .iter()
            .take(limit)
            .map(|q| s.search_full_trace(q).1)
            .collect()
    }

    /// Build the DB layout an engine variant needs. Low-dim payloads use
    /// the SQ8 codec (1 B/component) — what the store layer actually
    /// serves — so simulated DRAM traffic and energy reflect it.
    pub fn layout(&self, kind: LayoutKind) -> DbLayout {
        DbLayout::with_low_codec(&self.graph, kind, self.cfg.dim_low, self.base.dim(), Codec::Sq8)
    }

    /// Save the assembled index as a single `.phnsw` artifact (CSR graph
    /// + PCA + SQ8 low store + f32 high store). A server boots from this
    /// file via [`crate::runtime::Bundle::open`] without refitting anything.
    pub fn save_bundle(&self, path: impl AsRef<std::path::Path>) -> crate::Result<()> {
        let low = Sq8Store::from_set(&self.base_low);
        IndexBundle::save(path, &self.graph, &self.pca, &low, &self.base)
    }

    /// Save the assembled index in the v3 page-aligned `.phnsw` layout —
    /// the same sections as [`Workbench::save_bundle`], re-encoded so a
    /// server can serve them zero-copy from a memory mapping
    /// (`phnsw serve --mmap`). With `mid_stage` the bundle also carries
    /// the `MIDQ` cascade table (SQ8 over the high-dim corpus), enabling
    /// `Staged`-tier serving. `reorder` applies the locality pass on the
    /// way out: the graph, the stores, and the rerank rows are written
    /// hub-first with a `PERM` section recording the relabeling — the
    /// served results are identical, only the byte layout changes. The
    /// in-memory workbench stays corpus-order either way.
    pub fn save_bundle_v3(
        &self,
        path: impl AsRef<std::path::Path>,
        mid_stage: bool,
        reorder: crate::graph::ReorderMode,
    ) -> crate::Result<()> {
        use crate::graph::{Permutation, ReorderMode};
        let perm = match reorder {
            ReorderMode::None => None,
            ReorderMode::HubBfs => {
                let p = Permutation::hub_bfs(&self.graph);
                (!p.is_identity()).then_some(p)
            }
        };
        let Some(p) = perm else {
            let low = Sq8Store::from_set(&self.base_low);
            let mid = mid_stage.then(|| Sq8Store::from_set(&self.base));
            return crate::runtime::save_v3_single(
                path,
                &self.graph,
                &self.pca,
                &low,
                mid.as_ref().map(|m| m as &dyn VectorStore),
                None,
                &self.base,
            );
        };
        let graph = p.apply_to_graph(&self.graph)?;
        let high = p.apply_to_set(&self.base);
        // SQ8's per-dimension affine grid is a min/scale over all rows —
        // permutation invariant — so these are the corpus-order codes,
        // row-permuted.
        let low = Sq8Store::from_set(&self.pca.project_set(&high));
        let mid = mid_stage.then(|| Sq8Store::from_set(&high));
        crate::runtime::save_v3_single(
            path,
            &graph,
            &self.pca,
            &low,
            mid.as_ref().map(|m| m as &dyn VectorStore),
            Some(&p),
            &high,
        )
    }

    /// Build a segmented index over the workbench corpus, sharing the
    /// workbench's fitted PCA model — so the monolithic and segmented
    /// stacks filter in the *same* low-dim space and recall deltas are
    /// attributable to sharding alone.
    pub fn segmented(&self, spec: &crate::segment::SegmentSpec) -> crate::segment::SegmentedIndex {
        let bc = BuildConfig {
            m: self.cfg.m,
            ef_construction: self.cfg.ef_construction,
            ..Default::default()
        };
        crate::segment::build_segmented_with_pca(&self.base, &bc, self.pca.clone(), spec)
    }

    /// Run the processor simulation for one Table III cell.
    pub fn simulate(
        &self,
        engine: EngineKind,
        traces: &[SearchTrace],
        dram: DramConfig,
    ) -> WorkloadSim {
        let layout = self.layout(engine.layout_kind());
        let mut sim = DramSim::new(dram);
        simulate_workload(
            engine,
            traces,
            &layout,
            &mut sim,
            &CoreConfig::default(),
            &EnergyConfig::default(),
        )
    }
}

/// Recall/QPS result of one engine evaluation.
#[derive(Debug, Clone, Copy)]
pub struct EngineEval {
    /// Recall@k against exact ground truth.
    pub recall: f64,
    /// Wall-clock single-stream queries per second.
    pub qps: f64,
    /// Number of queries evaluated.
    pub queries: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wb() -> Workbench {
        Workbench::assemble(WorkbenchConfig {
            n_base: 3_000,
            n_queries: 40,
            ef_construction: 48,
            m: 8,
            ..WorkbenchConfig::default()
        })
        .unwrap()
    }

    #[test]
    fn assembles_consistent_stack() {
        let w = wb();
        assert_eq!(w.base.len(), 3_000);
        assert_eq!(w.base_low.len(), 3_000);
        assert_eq!(w.base_low.dim(), w.cfg.dim_low);
        assert_eq!(w.gt.len(), 40);
        assert!(w.graph.check_invariants().is_empty());
    }

    #[test]
    fn cache_roundtrip_is_stable() {
        let a = wb();
        let b = wb(); // second call loads from cache
        assert_eq!(a.graph.entry_point(), b.graph.entry_point());
        assert_eq!(a.gt, b.gt);
    }

    #[test]
    fn evaluate_reports_sane_recall() {
        let w = wb();
        let h = w.hnsw(SearchParams { ef_upper: 1, ef_l0: 32 });
        let e = w.evaluate(&h, 10);
        assert!(e.recall > 0.7, "recall {}", e.recall);
        assert!(e.qps > 0.0);
        assert_eq!(e.queries, 40);
    }

    #[test]
    fn traces_and_simulation_run() {
        let w = wb();
        let traces = w.phnsw_traces(PhnswParams::default(), 10);
        assert_eq!(traces.len(), 10);
        let sim = w.simulate(EngineKind::Phnsw, &traces, DramConfig::ddr4());
        assert!(sim.qps > 0.0);
        assert!(sim.mean_energy.total_pj() > 0.0);
    }
}
