//! Scaling studies — the paper's §VI future work, modeled:
//!
//! * **Multi-core pHNSW** ("scale the pHNSW processor to multi-core
//!   systems for multi-query search"): P cores run independent queries,
//!   sharing one DRAM channel. Compute scales linearly; the shared
//!   channel saturates when aggregate demand hits its bandwidth —
//!   classic bandwidth-wall behaviour.
//! * **Corpus scaling** (toward SIFT1B): per-query work in HNSW grows
//!   ≈ logarithmically with n while the inline DB grows linearly; this
//!   model projects QPS and footprint across n and flags where the DB no
//!   longer fits typical DRAM capacities (the paper's stated SIFT1B
//!   challenge: 512 GB raw — partitioning required).

use crate::dram::DramConfig;
use crate::hw::WorkloadSim;

/// Multi-core throughput projection from a single-core simulation.
#[derive(Debug, Clone)]
pub struct MultiCorePoint {
    /// Core count.
    pub cores: usize,
    /// Aggregate QPS.
    pub qps: f64,
    /// Fraction of the DRAM channel consumed (1.0 = saturated).
    pub dram_utilization: f64,
    /// Whether the point is bandwidth-bound.
    pub bandwidth_bound: bool,
}

/// Project multi-query throughput for `cores` replicas of the simulated
/// single-core engine sharing one `dram` channel.
///
/// Per-core demand is derived from the single-core run: bytes/query ×
/// QPS. Aggregate QPS = min(linear scaling, channel bandwidth / bytes
/// per query).
pub fn multicore(sim: &WorkloadSim, dram: &DramConfig, cores_list: &[usize]) -> Vec<MultiCorePoint> {
    let bytes_per_query = sim.dram.bytes as f64 / sim.queries as f64;
    let channel_bps = dram.bandwidth_gbps * 1e9;
    let qps_bw_cap = channel_bps / bytes_per_query.max(1.0);
    cores_list
        .iter()
        .map(|&cores| {
            let linear = sim.qps * cores as f64;
            let qps = linear.min(qps_bw_cap);
            MultiCorePoint {
                cores,
                qps,
                dram_utilization: (qps * bytes_per_query / channel_bps).min(1.0),
                bandwidth_bound: linear > qps_bw_cap,
            }
        })
        .collect()
}

/// Corpus-scaling projection point.
#[derive(Debug, Clone)]
pub struct CorpusPoint {
    /// Base corpus size.
    pub n: usize,
    /// Projected single-core QPS.
    pub qps: f64,
    /// Inline-layout DB footprint (bytes).
    pub db_bytes: u64,
    /// Fits in the modeled DRAM capacity?
    pub fits_dram: bool,
}

/// Project QPS and DB footprint across corpus sizes from one measured
/// anchor `(n0, sim)`.
///
/// HNSW per-query cost grows ≈ `log(n)` (hop count ∝ graph diameter);
/// the inline DB grows linearly (per-node cost is constant: capacity-
/// padded lists + inline payload + raw row).
pub fn corpus_scaling(
    n0: usize,
    sim: &WorkloadSim,
    db_bytes0: u64,
    dram_capacity_bytes: u64,
    ns: &[usize],
) -> Vec<CorpusPoint> {
    let per_node = db_bytes0 as f64 / n0 as f64;
    ns.iter()
        .map(|&n| {
            let slowdown = (n as f64).ln() / (n0 as f64).ln();
            let db_bytes = (per_node * n as f64) as u64;
            CorpusPoint {
                n,
                qps: sim.qps / slowdown,
                db_bytes,
                fits_dram: db_bytes <= dram_capacity_bytes,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::DramStats;
    use crate::energy::EnergyBreakdown;
    use crate::hw::isa::InstrMix;
    use crate::hw::EngineKind;
    use crate::search::SearchStats;

    fn fake_sim(qps: f64, bytes_per_query: u64, queries: usize) -> WorkloadSim {
        WorkloadSim {
            engine: EngineKind::Phnsw,
            dram_name: "DDR4",
            queries,
            mean_cycles: 1e9 / qps,
            qps,
            mean_energy: EnergyBreakdown::default(),
            mix: InstrMix::default(),
            dram: DramStats { bytes: bytes_per_query * queries as u64, ..Default::default() },
            stats: SearchStats::default(),
        }
    }

    #[test]
    fn multicore_scales_linearly_until_bandwidth_wall() {
        // 100k QPS × 100 KB/query = 10 GB/s per core; DDR4 (19.2 GB/s)
        // saturates just below 2 cores.
        let sim = fake_sim(100_000.0, 100_000, 10);
        let pts = multicore(&sim, &DramConfig::ddr4(), &[1, 2, 4, 8]);
        assert!(!pts[0].bandwidth_bound);
        assert!((pts[0].qps - 100_000.0).abs() < 1.0);
        assert!(pts[1].bandwidth_bound);
        let cap = 19.2e9 / 100_000.0;
        assert!((pts[3].qps - cap).abs() < 1.0, "capped at {} got {}", cap, pts[3].qps);
        assert!(pts[3].dram_utilization > 0.99);
    }

    #[test]
    fn multicore_hbm_extends_scaling() {
        let sim = fake_sim(100_000.0, 100_000, 10);
        let ddr = multicore(&sim, &DramConfig::ddr4(), &[4]);
        let hbm = multicore(&sim, &DramConfig::hbm(), &[4]);
        assert!(hbm[0].qps > 2.0 * ddr[0].qps, "HBM should push the wall out");
    }

    #[test]
    fn corpus_scaling_projects_log_slowdown_and_linear_db() {
        let sim = fake_sim(200_000.0, 50_000, 10);
        let pts = corpus_scaling(100_000, &sim, 250_000_000, 4 << 30, &[100_000, 1_000_000, 1_000_000_000]);
        assert!((pts[0].qps - 200_000.0).abs() < 1.0);
        assert!(pts[1].qps < pts[0].qps && pts[1].qps > pts[0].qps * 0.7);
        // 1B nodes × 2.5 KB/node = 2.5 TB ≫ 4 GB → partitioning needed,
        // exactly the paper's stated SIFT1B challenge.
        assert!(!pts[2].fits_dram);
        assert!(pts[0].fits_dram);
        assert_eq!(pts[1].db_bytes, 10 * pts[0].db_bytes / 10 * 10); // linear-ish sanity
        assert!(pts[1].db_bytes == pts[0].db_bytes * 10);
    }
}
