//! Parallel shard construction.
//!
//! Each shard's graph is built by the existing single-shard builder
//! ([`crate::graph::build::build`]) over that shard's rows only, so a
//! segment is exactly as deterministic as a monolithic build: shard `s`
//! draws its levels from a seed derived from `(cfg.seed, s)` and never
//! observes another shard's state. Shards are distributed over at most
//! `build_threads` scoped worker threads pulling from a shared counter —
//! the *schedule* varies with the thread count, the *artifacts* do not
//! (pinned by tests).

use super::{SegmentSpec, ShardMap};
use crate::dataset::VectorSet;
use crate::graph::build::{build, BuildConfig};
use crate::graph::{HnswGraph, Permutation, ReorderMode};
use crate::pca::PcaModel;
use crate::search::PhnswParams;
use crate::store::{Sq8Store, VectorStore};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

/// One shard of a segmented index: the graph plus both vector tables,
/// all speaking shard-local ids.
pub struct Segment {
    /// Frozen per-shard HNSW graph.
    pub graph: Arc<HnswGraph>,
    /// Shard rows in the original high-dim space (rerank table).
    pub high: Arc<VectorSet>,
    /// SQ8-quantized low-dim filter store (per-shard quantization grid).
    pub low: Arc<dyn VectorStore>,
    /// Mid-stage cascade table: SQ8 over the shard's *high*-dim rows
    /// (per-shard quantization grid, like `low`). Present only for
    /// mid-stage builds; `None` disables the staged cascade.
    pub mid: Option<Arc<dyn VectorStore>>,
    /// Locality relabeling applied to every table above: internal row
    /// `i` holds the shard-local row originally labeled `perm.ext(i)`.
    /// `None` means corpus order (identity) — the searcher then skips
    /// id translation entirely.
    pub perm: Option<Arc<Permutation>>,
}

/// A fully built segmented index: `S` independent segments plus the one
/// PCA model they share and the id mapping that stitches them together.
pub struct SegmentedIndex {
    /// PCA fitted on the full corpus (shared by every shard's searcher).
    pub pca: Arc<PcaModel>,
    /// The shards, indexed by shard id.
    pub segments: Vec<Segment>,
    /// Global ↔ (shard, local) id mapping.
    pub map: ShardMap,
}

impl SegmentedIndex {
    /// Total rows across all segments.
    pub fn len(&self) -> usize {
        self.map.n_total()
    }

    /// True if the index holds no rows.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of segments.
    pub fn n_segments(&self) -> usize {
        self.segments.len()
    }

    /// High-dim dimensionality (from the shared PCA model).
    pub fn dim(&self) -> usize {
        self.pca.dim()
    }

    /// Construct the fan-out/merge serving engine over this index.
    pub fn engine(&self, params: PhnswParams) -> super::SegmentedEngine {
        super::SegmentedEngine::new(self, params)
    }
}

/// Seed for shard `s`'s level draws. Shard 0 keeps the configured seed,
/// so an `S = 1` segmented build is bitwise identical to the monolithic
/// builder; higher shards step by the 64-bit golden ratio.
pub(crate) fn shard_seed(seed: u64, shard: usize) -> u64 {
    seed.wrapping_add((shard as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Copy shard `s`'s rows out of the corpus, in local-id order.
fn shard_rows(data: &VectorSet, map: &ShardMap, s: usize) -> VectorSet {
    let mut out = VectorSet::new(data.dim());
    out.reserve_rows(map.shard_len(s));
    for local in 0..map.shard_len(s) as u32 {
        out.push(data.row(map.global_of(s, local) as usize));
    }
    out
}

/// Split `data` into `spec.n_shards` segments and build each shard's
/// HNSW graph in parallel, fitting PCA on the full corpus first.
pub fn build_segmented(
    data: &VectorSet,
    bc: &BuildConfig,
    dim_low: usize,
    pca_seed: u64,
    spec: &SegmentSpec,
) -> SegmentedIndex {
    let pca = Arc::new(PcaModel::fit(data, dim_low, pca_seed));
    build_segmented_with_pca(data, bc, pca, spec)
}

/// [`build_segmented`] with an already-fitted PCA model (the workbench
/// path, which shares its model between the monolithic and segmented
/// stacks).
pub fn build_segmented_with_pca(
    data: &VectorSet,
    bc: &BuildConfig,
    pca: Arc<PcaModel>,
    spec: &SegmentSpec,
) -> SegmentedIndex {
    assert!(spec.n_shards >= 1, "need at least one shard");
    assert_eq!(pca.dim(), data.dim(), "PCA input dim mismatch");
    let map = ShardMap::new(spec.assignment, data.len(), spec.n_shards);
    let s_total = spec.n_shards;
    let workers = spec.build_threads.clamp(1, s_total);
    let mid_stage = spec.mid_stage;
    let reorder = spec.reorder;

    // Dynamic shard queue: workers pull the next shard index from a
    // shared counter and report finished segments over a channel. The
    // schedule depends on the thread count; the segments do not — each
    // is a pure function of (data, bc, pca, shard id).
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, Segment)>();
    let mut slots: Vec<Option<Segment>> = Vec::with_capacity(s_total);
    slots.resize_with(s_total, || None);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let next = &next;
            let pca = &pca;
            let map = &map;
            scope.spawn(move || loop {
                let s = next.fetch_add(1, Ordering::Relaxed);
                if s >= s_total {
                    break;
                }
                let high = shard_rows(data, map, s);
                let cfg = BuildConfig { seed: shard_seed(bc.seed, s), ..bc.clone() };
                let graph = build(&high, &cfg);
                // Locality pass: relabel the graph hub-first and move the
                // high rows with it BEFORE quantizing, so the SQ8 tables
                // below inherit the same row order. The per-dimension
                // affine grid is a min/scale over all rows — permutation
                // invariant — so reordered codes are the identity build's
                // codes, just byte-adjacent to their graph neighbors.
                let (graph, high, perm) = match reorder {
                    ReorderMode::None => (graph, high, None),
                    ReorderMode::HubBfs => {
                        let p = Permutation::hub_bfs(&graph);
                        if p.is_identity() {
                            (graph, high, None)
                        } else {
                            let g = p
                                .apply_to_graph(&graph)
                                .expect("hub-bfs permutation covers its own graph");
                            let h = p.apply_to_set(&high);
                            (g, h, Some(Arc::new(p)))
                        }
                    }
                };
                let low: Arc<dyn VectorStore> =
                    Arc::new(Sq8Store::from_set(&pca.project_set(&high)));
                // Mid stage: quantize the shard's own high-dim rows, so
                // the affine grid adapts to each shard's density (the
                // live tier instead derives its grid from the PCA model
                // for insert-time determinism).
                let mid: Option<Arc<dyn VectorStore>> =
                    mid_stage.then(|| Arc::new(Sq8Store::from_set(&high)) as _);
                let seg = Segment { graph: Arc::new(graph), high: Arc::new(high), low, mid, perm };
                if tx.send((s, seg)).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        for (s, seg) in rx {
            slots[s] = Some(seg);
        }
    });
    let segments: Vec<Segment> =
        slots.into_iter().map(|s| s.expect("worker built every shard")).collect();
    SegmentedIndex { pca, segments, map }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic::{generate, SyntheticConfig};
    use crate::segment::ShardAssignment;

    fn corpus(n: usize) -> VectorSet {
        let cfg = SyntheticConfig { n_base: n, n_queries: 1, ..SyntheticConfig::tiny() };
        generate(&cfg).0
    }

    fn spec(s: usize, t: usize) -> SegmentSpec {
        SegmentSpec {
            n_shards: s,
            build_threads: t,
            assignment: ShardAssignment::RoundRobin,
            ..Default::default()
        }
    }

    #[test]
    fn builds_every_shard_with_its_rows() {
        let data = corpus(500);
        let bc = BuildConfig { m: 4, ef_construction: 16, ..Default::default() };
        let idx = build_segmented(&data, &bc, 4, 7, &spec(3, 2));
        assert_eq!(idx.n_segments(), 3);
        assert_eq!(idx.len(), 500);
        for (s, seg) in idx.segments.iter().enumerate() {
            assert_eq!(seg.graph.len(), idx.map.shard_len(s));
            assert_eq!(seg.high.len(), seg.graph.len());
            assert_eq!(seg.low.len(), seg.graph.len());
            assert!(seg.graph.is_frozen());
            assert!(seg.graph.check_invariants().is_empty());
            // Shard rows are the mapped corpus rows, verbatim.
            for local in [0u32, seg.high.len() as u32 / 2] {
                let g = idx.map.global_of(s, local) as usize;
                assert_eq!(seg.high.row(local as usize), data.row(g));
            }
        }
    }

    #[test]
    fn shard_zero_matches_monolithic_build_when_s_is_one() {
        let data = corpus(400);
        let bc = BuildConfig { m: 6, ef_construction: 24, ..Default::default() };
        let mono = build(&data, &bc);
        let idx = build_segmented(&data, &bc, 4, 7, &spec(1, 1));
        let seg = &idx.segments[0].graph;
        assert_eq!(seg.entry_point(), mono.entry_point());
        for n in 0..mono.len() as u32 {
            assert_eq!(seg.level(n), mono.level(n));
            for l in 0..=mono.level(n) {
                assert_eq!(seg.neighbors(n, l), mono.neighbors(n, l));
            }
        }
    }

    #[test]
    fn more_shards_than_rows_leaves_empty_segments() {
        let data = corpus(3);
        let bc = BuildConfig { m: 4, ef_construction: 8, ..Default::default() };
        let idx = build_segmented(&data, &bc, 2, 1, &spec(5, 4));
        assert_eq!(idx.n_segments(), 5);
        assert_eq!(idx.len(), 3);
        assert!(idx.segments[4].graph.is_empty());
        assert_eq!(idx.segments[0].graph.len(), 1);
    }
}
